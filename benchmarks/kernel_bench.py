"""Kernel micro-benchmarks: Pallas (interpret) vs jnp reference oracles.

On CPU the interpret-mode numbers are correctness/plumbing benchmarks, not
TPU performance; the TPU-side expectation is derived analytically in
EXPERIMENTS.md (VMEM-resident state removes the HBM round-trips that
dominate the jnp paths).

The plan-kernel rows are *real* CPU performance claims, though: the fused
filter+sketch numpy path must beat the two-pass mask-then-sketch baseline
at equal results, and the autotuned tile must beat (or tie) the retired
hardcoded 128-row tile.  ``--smoke`` enforces both::

    PYTHONPATH=src python -m benchmarks.kernel_bench            # rows only
    PYTHONPATH=src python -m benchmarks.kernel_bench --smoke    # CI gate
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

Row = tuple  # (name, value, derived[, metrics dict])

# the --smoke gate: fused one-pass filter+sketch vs the two-pass baseline
PLAN_SPEEDUP_GATE = 1.5
# autotuned tile must be within this factor of hardcoded 128 (ties logged)
TILE_TIE_MARGIN = 1.05


def _timeit(fn, repeat=3) -> float:
    fn()
    t0 = time.perf_counter()
    for _ in range(repeat):
        fn()
    return (time.perf_counter() - t0) / repeat * 1e6


def _best_of(fn, repeat=5) -> float:
    """Min-of-N microseconds -- the noise-robust timer the gates use (mean
    timings on a loaded CI host have inflated perf ratios by 5x before)."""
    fn()
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def bench_flash_attention() -> list[Row]:
    from repro.kernels.flash_attention import ops as fa
    from repro.kernels.flash_attention.ref import flash_attention_ref

    rows = []
    B, H, Hkv, S, D = 1, 8, 2, 512, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, S, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Hkv, S, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Hkv, S, D), jnp.float32)
    flops = 4 * B * H * S * S * D * 0.5
    us = _timeit(lambda: jax.block_until_ready(
        fa.flash_attention(q, k, v, causal=True, block_q=128, block_k=128)), repeat=1)
    rows.append(("flash_attn_pallas_interp_512", us, f"gflops={flops / (us / 1e6) / 1e9:.2f}"))
    ref = jax.jit(lambda q, k, v: flash_attention_ref(q, k, v, causal=True))
    us = _timeit(lambda: jax.block_until_ready(ref(q, k, v)))
    rows.append(("flash_attn_ref_jnp_512", us, f"gflops={flops / (us / 1e6) / 1e9:.2f}"))
    return rows


def bench_rsp_shuffle() -> list[Row]:
    from repro.kernels.rsp_shuffle import ops as rs

    rows = []
    R, D, T = 65_536, 32, 256
    x = jax.random.normal(jax.random.PRNGKey(0), (R, D), jnp.float32)
    tp, ip = rs.make_permutations(jax.random.PRNGKey(1), R // T, T)
    gb = R * D * 4 / 1e9
    us = _timeit(lambda: jax.block_until_ready(rs.rsp_shuffle(x, tp, ip, tile_rows=T)), repeat=1)
    rows.append(("rsp_shuffle_pallas_interp_64k", us, f"gbps={gb / (us / 1e6):.3f}"))
    gather = jax.jit(lambda x, idx: x[idx])
    idx = jax.random.permutation(jax.random.PRNGKey(2), R)
    us = _timeit(lambda: jax.block_until_ready(gather(x, idx)))
    rows.append(("rsp_shuffle_xla_gather_64k", us, f"gbps={gb / (us / 1e6):.3f}"))
    return rows


def bench_ssd_and_wkv() -> list[Row]:
    from repro.kernels.mamba2_ssd import ops as ssd_ops
    from repro.models.mamba2 import ssd_chunked, ssd_reference
    from repro.kernels.rwkv6_wkv import ops as wkv_ops
    from repro.models.rwkv6 import wkv6_scan

    rows = []
    B, L, H, P, N = 1, 512, 8, 64, 64
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    xbar = jax.random.normal(ks[0], (B, L, H, P))
    dA = -jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    Bm = jax.random.normal(ks[2], (B, L, N))
    Cm = jax.random.normal(ks[3], (B, L, N))
    us = _timeit(lambda: jax.block_until_ready(ssd_ops.ssd(xbar, dA, Bm, Cm, chunk=128)), repeat=1)
    rows.append(("mamba2_ssd_pallas_interp_L512", us, ""))
    ref = jax.jit(lambda *a: ssd_chunked(*a, chunk=128))
    us = _timeit(lambda: jax.block_until_ready(ref(xbar, dA, Bm, Cm)))
    rows.append(("mamba2_ssd_jnp_chunked_L512", us, ""))
    scan = jax.jit(ssd_reference)
    us = _timeit(lambda: jax.block_until_ready(scan(xbar, dA, Bm, Cm)))
    rows.append(("mamba2_ssd_jnp_scan_L512", us, ""))

    C = 64
    r = jax.random.normal(ks[0], (B, L, H, C))
    k2 = jax.random.normal(ks[1], (B, L, H, C))
    v2 = jax.random.normal(ks[2], (B, L, H, C))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, L, H, C)))
    u = jnp.full((H, C), 0.3)
    us = _timeit(lambda: jax.block_until_ready(wkv_ops.wkv6(r, k2, v2, w, u, chunk=16)), repeat=1)
    rows.append(("rwkv6_wkv_pallas_interp_L512", us, ""))
    scan2 = jax.jit(wkv6_scan)
    us = _timeit(lambda: jax.block_until_ready(scan2(r, k2, v2, w, u)))
    rows.append(("rwkv6_wkv_jnp_scan_L512", us, ""))
    return rows


def bench_block_sketch() -> list[Row]:
    from repro.kernels.block_sketch import block_sketch
    from repro.kernels.block_sketch.kernel import block_sketch_pallas

    rows = []
    n, f, bins = 16_384, 16, 128
    x = jax.random.normal(jax.random.PRNGKey(4), (n, f), jnp.float32) * 2.0 + 1.5
    lo = jnp.full((f,), -8.0)
    inv_w = jnp.full((f,), bins / 16.0)
    gb = n * f * 4 / 1e9
    us = _timeit(
        lambda: jax.block_until_ready(
            block_sketch_pallas(x, lo, inv_w, bins=bins, tile_rows=512)[0]
        ),
        repeat=1,
    )
    rows.append(("block_sketch_pallas_interp_16k", us, f"gbps={gb / (us / 1e6):.3f}"))
    xs = np.asarray(x)
    us = _timeit(lambda: block_sketch(xs, bins=bins, lo=-8.0, hi=8.0, impl="jax"))
    rows.append(("block_sketch_jax_fused_16k", us, f"gbps={gb / (us / 1e6):.3f}"))
    return rows


def _plan_close(got, ref, *, tol: float = 1e-5) -> None:
    """Assert two PlanResults agree: counts exactly, moments to ``tol``
    (the repo-wide fused-kernel parity bar), histogram mass exactly."""
    assert got.rows_selected == ref.rows_selected, (got.rows_selected, ref.rows_selected)
    for g, r in zip(got.sketches, ref.sketches):
        assert g.count == r.count
        np.testing.assert_allclose(g.mean, r.mean, rtol=tol, atol=tol)
        np.testing.assert_allclose(g.m2, r.m2, rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(g.min, r.min, rtol=tol, atol=tol)
        np.testing.assert_allclose(g.max, r.max, rtol=tol, atol=tol)
        if g.hist is not None:
            assert g.hist.sum() == r.hist.sum()


def plan_bench() -> tuple[list[Row], dict]:
    """Fused plan kernels vs the mask-then-sketch two-pass baseline; returns
    ``(rows, gates)`` where ``gates`` carries everything ``--smoke`` needs."""
    from repro.kernels.plan import QueryPlan, plan_sketch, plan_sketch_ref

    n, f, bins = 200_000, 8, 64
    rng = np.random.default_rng(0)
    x = rng.normal(1.5, 2.0, (n, f)).astype(np.float32)
    plan = QueryPlan(predicates=((0, "gt", 1.0), (3, "lt", 2.5)))
    kw = dict(bins=bins, lo=-8.0, hi=12.0)

    ref = plan_sketch_ref(x, plan, **kw)

    def run_np(tile):
        return plan_sketch(x, plan, impl="np", tile_rows=tile, **kw)

    # equal results first: a speedup over wrong answers is not a speedup
    _plan_close(run_np(None), ref)

    us_ref = _best_of(lambda: plan_sketch_ref(x, plan, **kw))
    tiles = (8192, 16384, 32768, 65536)
    times = {t: _best_of(lambda t=t: run_np(t)) for t in tiles}
    best_tile = min(times, key=times.get)
    us_fused = times[best_tile]
    us_128 = _best_of(lambda: run_np(128))
    speedup = us_ref / us_fused
    tile_ratio = us_128 / us_fused
    winning = {"impl": "np", "tile_rows": best_tile, "us": round(us_fused, 1)}

    # grouped variant (informational: per-class flatnonzero/take overhead
    # makes fused ~parity with ref on CPU; the autotuner picks ref there)
    xg = x.copy()
    xg[:, -1] = rng.integers(0, 8, n)
    gplan = QueryPlan(
        predicates=plan.predicates, group_by=f - 1, num_classes=8
    )
    gref = plan_sketch_ref(xg, gplan, **kw)
    _plan_close(plan_sketch(xg, gplan, impl="np", tile_rows=32768, **kw), gref)
    us_g = _best_of(lambda: plan_sketch(xg, gplan, impl="np", tile_rows=32768, **kw))
    us_g_ref = _best_of(lambda: plan_sketch_ref(xg, gplan, **kw))

    # jax + pallas-interpret parity/plumbing rows (small shape: interpret
    # mode is an emulator, timing it at 200k rows is all noise)
    xs = x[:16_384]
    sref = plan_sketch_ref(xs, plan, **kw)
    _plan_close(plan_sketch(xs, plan, impl="jax", **kw), sref)
    us_jax = _best_of(lambda: plan_sketch(xs, plan, impl="jax", **kw), repeat=3)
    _plan_close(plan_sketch(xs, plan, impl="pallas", tile_rows=512, **kw), sref)
    us_pl = _timeit(
        lambda: plan_sketch(xs, plan, impl="pallas", tile_rows=512, **kw), repeat=1
    )

    rows: list[Row] = [
        (
            "plan_fused_filter_200k",
            us_fused,
            f"speedup={speedup:.2f}x vs two-pass sel={ref.selectivity:.2f}"
            f" tile={best_tile}",
            {"rows_per_s": n / (us_fused / 1e6), "autotune": winning},
        ),
        (
            "plan_twopass_baseline_200k",
            us_ref,
            f"mask-then-sketch ref rows_per_s={n / (us_ref / 1e6):,.0f}",
            {"rows_per_s": n / (us_ref / 1e6)},
        ),
        (
            "plan_tile128_retired_200k",
            us_128,
            f"hardcoded tile128={us_128:.0f}us autotuned={us_fused:.0f}us"
            f" ratio={tile_ratio:.2f}x"
            + (" (tie)" if tile_ratio <= TILE_TIE_MARGIN else ""),
            {"rows_per_s": n / (us_128 / 1e6), "autotune": winning},
        ),
        (
            "plan_fused_grouped_200k",
            us_g,
            f"8-class grouped ratio={us_g_ref / us_g:.2f}x vs two-pass",
            {"rows_per_s": n / (us_g / 1e6)},
        ),
        (
            "plan_fused_jax_16k",
            us_jax,
            f"rows_per_s={len(xs) / (us_jax / 1e6):,.0f}",
            {"rows_per_s": len(xs) / (us_jax / 1e6)},
        ),
        ("plan_pallas_interp_16k", us_pl, "interpret-mode plumbing number", {}),
    ]
    gates = {
        "plan_speedup": speedup,
        "plan_speedup_gate": PLAN_SPEEDUP_GATE,
        "tile128_over_autotuned": tile_ratio,
        "tile_tie_margin": TILE_TIE_MARGIN,
        "tile_tie": bool(tile_ratio <= TILE_TIE_MARGIN),
        "winning_config": winning,
        "parity": "checked (1e-5 moments, exact counts/hist mass)",
    }
    return rows, gates


def bench_plan_kernels() -> list[Row]:
    return plan_bench()[0]


ALL_KERNELS = [
    bench_flash_attention,
    bench_rsp_shuffle,
    bench_ssd_and_wkv,
    bench_block_sketch,
    bench_plan_kernels,
]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke", action="store_true",
        help="plan-kernel rows only + hard pass/fail perf gates",
    )
    args = ap.parse_args()
    from benchmarks.artifact import write_artifact

    if args.smoke:
        rows, gates = plan_bench()
    else:
        rows = [r for fn in ALL_KERNELS for r in fn()]
        gates = None
    print("name,us_per_call,derived")
    for row in rows:
        print(f"{row[0]},{row[1]:.1f},{row[2]}")
    extra = {"smoke": args.smoke}
    if gates is not None:
        extra["gates"] = gates
    path = write_artifact("kernels", rows, extra=extra)
    print(f"wrote {path}")

    if args.smoke:
        failures = []
        if gates["plan_speedup"] < PLAN_SPEEDUP_GATE:
            failures.append(
                f"fused filter kernel only {gates['plan_speedup']:.2f}x the"
                f" two-pass baseline (< {PLAN_SPEEDUP_GATE}x)"
            )
        if gates["tile128_over_autotuned"] < 1.0 / TILE_TIE_MARGIN:
            failures.append(
                f"autotuned tile {gates['winning_config']['tile_rows']} is"
                f" slower than hardcoded 128"
                f" (ratio {gates['tile128_over_autotuned']:.2f})"
            )
        for msg in failures:
            print(f"SMOKE FAIL: {msg}", file=sys.stderr)
        if failures:
            sys.exit(1)
        tie = " (tie)" if gates["tile_tie"] else ""
        print(
            f"SMOKE OK: fused filter {gates['plan_speedup']:.2f}x >="
            f" {PLAN_SPEEDUP_GATE}x two-pass at equal results; autotuned"
            f" tile {gates['winning_config']['tile_rows']} vs hardcoded 128:"
            f" {gates['tile128_over_autotuned']:.2f}x{tie}"
        )


if __name__ == "__main__":
    main()
