"""Kernel micro-benchmarks: Pallas (interpret) vs jnp reference oracles.

On CPU the interpret-mode numbers are correctness/plumbing benchmarks, not
TPU performance; the TPU-side expectation is derived analytically in
EXPERIMENTS.md (VMEM-resident state removes the HBM round-trips that
dominate the jnp paths).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

Row = tuple[str, float, str]


def _timeit(fn, repeat=3) -> float:
    fn()
    t0 = time.perf_counter()
    for _ in range(repeat):
        fn()
    return (time.perf_counter() - t0) / repeat * 1e6


def bench_flash_attention() -> list[Row]:
    from repro.kernels.flash_attention import ops as fa
    from repro.kernels.flash_attention.ref import flash_attention_ref

    rows = []
    B, H, Hkv, S, D = 1, 8, 2, 512, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, S, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Hkv, S, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Hkv, S, D), jnp.float32)
    flops = 4 * B * H * S * S * D * 0.5
    us = _timeit(lambda: jax.block_until_ready(
        fa.flash_attention(q, k, v, causal=True, block_q=128, block_k=128)), repeat=1)
    rows.append(("flash_attn_pallas_interp_512", us, f"gflops={flops / (us / 1e6) / 1e9:.2f}"))
    ref = jax.jit(lambda q, k, v: flash_attention_ref(q, k, v, causal=True))
    us = _timeit(lambda: jax.block_until_ready(ref(q, k, v)))
    rows.append(("flash_attn_ref_jnp_512", us, f"gflops={flops / (us / 1e6) / 1e9:.2f}"))
    return rows


def bench_rsp_shuffle() -> list[Row]:
    from repro.kernels.rsp_shuffle import ops as rs

    rows = []
    R, D, T = 65_536, 32, 256
    x = jax.random.normal(jax.random.PRNGKey(0), (R, D), jnp.float32)
    tp, ip = rs.make_permutations(jax.random.PRNGKey(1), R // T, T)
    gb = R * D * 4 / 1e9
    us = _timeit(lambda: jax.block_until_ready(rs.rsp_shuffle(x, tp, ip, tile_rows=T)), repeat=1)
    rows.append(("rsp_shuffle_pallas_interp_64k", us, f"gbps={gb / (us / 1e6):.3f}"))
    gather = jax.jit(lambda x, idx: x[idx])
    idx = jax.random.permutation(jax.random.PRNGKey(2), R)
    us = _timeit(lambda: jax.block_until_ready(gather(x, idx)))
    rows.append(("rsp_shuffle_xla_gather_64k", us, f"gbps={gb / (us / 1e6):.3f}"))
    return rows


def bench_ssd_and_wkv() -> list[Row]:
    from repro.kernels.mamba2_ssd import ops as ssd_ops
    from repro.models.mamba2 import ssd_chunked, ssd_reference
    from repro.kernels.rwkv6_wkv import ops as wkv_ops
    from repro.models.rwkv6 import wkv6_scan

    rows = []
    B, L, H, P, N = 1, 512, 8, 64, 64
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    xbar = jax.random.normal(ks[0], (B, L, H, P))
    dA = -jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    Bm = jax.random.normal(ks[2], (B, L, N))
    Cm = jax.random.normal(ks[3], (B, L, N))
    us = _timeit(lambda: jax.block_until_ready(ssd_ops.ssd(xbar, dA, Bm, Cm, chunk=128)), repeat=1)
    rows.append(("mamba2_ssd_pallas_interp_L512", us, ""))
    ref = jax.jit(lambda *a: ssd_chunked(*a, chunk=128))
    us = _timeit(lambda: jax.block_until_ready(ref(xbar, dA, Bm, Cm)))
    rows.append(("mamba2_ssd_jnp_chunked_L512", us, ""))
    scan = jax.jit(ssd_reference)
    us = _timeit(lambda: jax.block_until_ready(scan(xbar, dA, Bm, Cm)))
    rows.append(("mamba2_ssd_jnp_scan_L512", us, ""))

    C = 64
    r = jax.random.normal(ks[0], (B, L, H, C))
    k2 = jax.random.normal(ks[1], (B, L, H, C))
    v2 = jax.random.normal(ks[2], (B, L, H, C))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, L, H, C)))
    u = jnp.full((H, C), 0.3)
    us = _timeit(lambda: jax.block_until_ready(wkv_ops.wkv6(r, k2, v2, w, u, chunk=16)), repeat=1)
    rows.append(("rwkv6_wkv_pallas_interp_L512", us, ""))
    scan2 = jax.jit(wkv6_scan)
    us = _timeit(lambda: jax.block_until_ready(scan2(r, k2, v2, w, u)))
    rows.append(("rwkv6_wkv_jnp_scan_L512", us, ""))
    return rows


def bench_block_sketch() -> list[Row]:
    from repro.kernels.block_sketch import block_sketch
    from repro.kernels.block_sketch.kernel import block_sketch_pallas

    rows = []
    n, f, bins = 16_384, 16, 128
    x = jax.random.normal(jax.random.PRNGKey(4), (n, f), jnp.float32) * 2.0 + 1.5
    lo = jnp.full((f,), -8.0)
    inv_w = jnp.full((f,), bins / 16.0)
    gb = n * f * 4 / 1e9
    us = _timeit(
        lambda: jax.block_until_ready(
            block_sketch_pallas(x, lo, inv_w, bins=bins, tile_rows=512)[0]
        ),
        repeat=1,
    )
    rows.append(("block_sketch_pallas_interp_16k", us, f"gbps={gb / (us / 1e6):.3f}"))
    xs = np.asarray(x)
    us = _timeit(lambda: block_sketch(xs, bins=bins, lo=-8.0, hi=8.0, impl="jax"))
    rows.append(("block_sketch_jax_fused_16k", us, f"gbps={gb / (us / 1e6):.3f}"))
    return rows


ALL_KERNELS = [bench_flash_attention, bench_rsp_shuffle, bench_ssd_and_wkv, bench_block_sketch]
