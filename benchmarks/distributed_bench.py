"""Distributed-query benchmark: block-parallel fan-out and straggler
tolerance over an emulated multi-host mesh.

An in-process ``LocalTransport`` mesh (one thread per host, one shared KV
plane -- the same protocol code a real ``jax.distributed`` mesh runs)
answers a progressive weighted query over a store whose fetches carry an
emulated per-block I/O latency.  Reported rows:

* **distributed_fanout** -- wall-clock speedup of a 4-host mesh over the
  1-host run of the identical query: each host streams only its owned
  blocks, so block I/O overlaps across the mesh while every host still
  folds the full payload sequence.
* **distributed_straggler** -- a host is fault-injected dead mid-query;
  survivors steal its leases after the grace deadline.  The row records
  whether the surviving hosts' answer is *bit-identical* to the single-host
  reference (Theorem 1: re-assigning exchangeable blocks is statistically
  free, so a death may cost time but never accuracy).

``results/bench/BENCH_distributed.json`` is written on every run.

Usage::

    PYTHONPATH=src python -m benchmarks.distributed_bench            # full sizes
    PYTHONPATH=src python -m benchmarks.distributed_bench --smoke    # CI gate

``--smoke`` exits non-zero unless the 4-host fan-out beats the 1-host
wall-clock by >= 1.5x on the emulated-latency store and the killed
straggler changes no estimate bit (estimates, CI endpoints, stopping
point all exactly equal).
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from benchmarks.artifact import write_artifact
from repro.distributed import LocalTransport, run_local_hosts
from repro.rsp.dataset import RSPDataset

SPEEDUP_GATE = 1.5


class _SlowFetcher:
    """Fetcher wrapper emulating per-block store latency (remote object
    store / cold disk): every fetch sleeps before delegating."""

    def __init__(self, inner, delay_s: float):
        self._inner = inner
        self._delay_s = delay_s

    @property
    def num_blocks(self) -> int:
        return self._inner.num_blocks

    def fetch(self, block_id: int) -> np.ndarray:
        time.sleep(self._delay_s)
        return self._inner.fetch(block_id)


def _make_ds(n: int, blocks: int, *, delay_s: float) -> RSPDataset:
    rng = np.random.default_rng(7)
    data = rng.normal(size=(n, 4)).astype(np.float32)
    data[:, 2] = rng.gamma(2.0, 1.0, size=n).astype(np.float32)
    ds = RSPDataset.partition(data, blocks, seed=3)
    inner_factory = ds._make_fetcher
    ds._make_fetcher = lambda: _SlowFetcher(inner_factory(), delay_s)  # type: ignore[method-assign]
    return ds


def _sig(r) -> str:
    return json.dumps(
        {
            "est": {a.name: np.asarray(a.estimate).ravel().tolist() for a in r.aggregates},
            "lo": {
                a.name: None if a.ci_lo is None else np.asarray(a.ci_lo).ravel().tolist()
                for a in r.aggregates
            },
            "hi": {
                a.name: None if a.ci_hi is None else np.asarray(a.ci_hi).ravel().tolist()
                for a in r.aggregates
            },
            "blocks_read": r.blocks_read,
            "converged": r.converged,
        },
        sort_keys=True,
    )


def _mesh_query(ds, num_hosts: int, query: dict, *, kill: tuple[int, int] | None = None):
    """Run the query on an emulated ``num_hosts`` mesh; returns (signatures
    of surviving hosts' results, wall seconds)."""
    transports = LocalTransport.group(num_hosts)
    if kill is not None:
        transports[kill[0]].kill_after_puts(kill[1])

    def run(t):
        dds = ds.distribute(t, straggler_grace=1.0, poll_interval=0.005)
        return _sig(dds.query(**query))

    t0 = time.perf_counter()
    results = run_local_hosts(transports, run)
    wall = time.perf_counter() - t0
    return [r for r in results if r is not None], wall


def distributed_bench(smoke: bool = False):
    """Returns (rows, gates)."""
    # delay emulates a remote object store / cold disk; it must dominate the
    # (GIL-serialized) fold CPU for fan-out to show -- that is the regime
    # block-parallel distribution targets
    if smoke:
        n, blocks, delay_s = 32768, 32, 0.15
    else:
        n, blocks, delay_s = 131072, 64, 0.15
    query = dict(
        aggregates=["mean", "p95"], target_rel_err=1e-6, seed=11,
        policy="weighted", where="c2 > 0.5", max_blocks=blocks,
    )

    ds = _make_ds(n, blocks, delay_s=delay_s)
    ref = _sig(ds.query(**query))

    solo_sigs, solo_wall = _mesh_query(ds, 1, query)
    fan_sigs, fan_wall = _mesh_query(ds, 4, query)
    speedup = solo_wall / max(fan_wall, 1e-9)
    fanout_identical = all(s == ref for s in solo_sigs + fan_sigs)

    # straggler: host 3 dies after publishing 2 payloads; survivors steal
    surv_sigs, surv_wall = _mesh_query(ds, 4, query, kill=(3, 2))
    straggler_identical = len(surv_sigs) == 3 and all(s == ref for s in surv_sigs)

    rows = [
        (
            "distributed_fanout",
            speedup,
            f"hosts=4 blocks={blocks} delay_ms={delay_s * 1e3:.0f}"
            f" solo_s={solo_wall:.2f} mesh_s={fan_wall:.2f}"
            f" bit_identical={fanout_identical}",
            {"solo_wall_s": solo_wall, "mesh_wall_s": fan_wall},
        ),
        (
            "distributed_straggler",
            float(straggler_identical),
            f"killed_host=3 survivors={len(surv_sigs)}"
            f" wall_s={surv_wall:.2f} bit_identical={straggler_identical}",
            {"survivor_wall_s": surv_wall},
        ),
    ]
    gates = {
        "speedup": speedup,
        "speedup_gate": SPEEDUP_GATE,
        "fanout_bit_identical": bool(fanout_identical),
        "straggler_survivors": len(surv_sigs),
        "straggler_bit_identical": bool(straggler_identical),
    }
    return rows, gates


def distributed_rows(smoke: bool = False) -> list[tuple]:
    """``benchmarks.run``-style rows ``(name, value, derived[, metrics])``."""
    return distributed_bench(smoke=smoke)[0]


def _verdict(gates: dict) -> list[str]:
    failures = []
    if not gates["speedup"] >= gates["speedup_gate"]:
        failures.append(
            f"4-host fan-out speedup {gates['speedup']:.2f}x below"
            f" {gates['speedup_gate']:.1f}x gate"
        )
    if not gates["fanout_bit_identical"]:
        failures.append("mesh answer differs from the single-host reference")
    if gates["straggler_survivors"] != 3:
        failures.append(
            f"{gates['straggler_survivors']} survivors after one injected death (want 3)"
        )
    if not gates["straggler_bit_identical"]:
        failures.append("killed straggler changed an estimate bit")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke", action="store_true", help="CI sizes + hard pass/fail gate"
    )
    args = ap.parse_args()

    rows, gates = distributed_bench(smoke=args.smoke)
    print("name,value,derived")
    for row in rows:
        print(f"{row[0]},{row[1]:.2f},{row[2]}")
    path = write_artifact(
        "distributed", rows, extra={"gates": gates, "smoke": args.smoke}
    )
    print(f"wrote {path}")

    if args.smoke:
        failures = _verdict(gates)
        for msg in failures:
            print(f"SMOKE FAIL: {msg}", file=sys.stderr)
        if failures:
            sys.exit(1)
        print(
            f"SMOKE OK: 4-host fan-out {gates['speedup']:.2f}x >="
            f" {gates['speedup_gate']:.1f}x; killed straggler changed no"
            f" estimate bit ({gates['straggler_survivors']} survivors)"
        )


if __name__ == "__main__":
    main()
