"""Benchmark harness: one function per paper table/figure plus kernel and
dry-run/roofline tables.  Prints ``name,us_per_call,derived`` CSV and writes
one machine-readable ``results/bench/BENCH_<suite>.json`` artifact per suite
executed (see :mod:`benchmarks.artifact`).

    PYTHONPATH=src python -m benchmarks.run             # everything
    PYTHONPATH=src python -m benchmarks.run fig6 kernels
"""

from __future__ import annotations

import json
import os
import sys
import traceback

from benchmarks.artifact import write_artifact


def _roofline_rows() -> list[tuple[str, float, str]]:
    """Summarize results/dryrun/*.json (if the dry-run sweep has run)."""
    rows: list[tuple[str, float, str]] = []
    root = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
    if not os.path.isdir(root):
        return [("roofline_table", 0.0, "results/dryrun missing -- run repro.launch.dryrun --all")]
    from repro.configs import ARCHS, SHAPES
    from repro.launch.roofline import summarize_cell

    for name in sorted(os.listdir(root)):
        if not name.endswith(".json"):
            continue
        with open(os.path.join(root, name)) as f:
            r = json.load(f)
        if "skipped" in r or "analysis" not in r:
            continue
        if r.get("arch") not in ARCHS:
            continue
        cfg, cell = ARCHS[r["arch"]], SHAPES[r["shape"]]
        t = summarize_cell(r, cfg, cell)
        rows.append((
            f"roofline_{name[:-5]}",
            t["step_time_s"] * 1e6,
            f"dom={t['dominant']} frac={t['roofline_fraction']:.3f} "
            f"useful={t['useful_ratio']:.2f}",
        ))
    return rows


SUITES = {}


def _register_suites():
    from benchmarks.paper_figs import ALL_FIGS
    from benchmarks.kernel_bench import ALL_KERNELS
    from benchmarks.distributed_bench import distributed_rows
    from benchmarks.engine_bench import engine_rows
    from benchmarks.ingest_bench import ingest_rows
    from benchmarks.obs_bench import obs_rows
    from benchmarks.query_bench import query_rows
    from benchmarks.serve_bench import serve_rows
    from benchmarks.sketch_bench import sketch_rows

    SUITES.update({
        "distributed": [distributed_rows],
        "engine": [engine_rows],
        "ingest": [ingest_rows],
        "obs": [obs_rows],
        "query": [query_rows],
        "serve": [serve_rows],
        "sketch": [sketch_rows],
        "fig1": [ALL_FIGS[0]],
        "fig2": [ALL_FIGS[1]],
        "fig34": [ALL_FIGS[2]],
        "fig6": [ALL_FIGS[3]],
        "fig7": [ALL_FIGS[4]],
        "paper": ALL_FIGS,
        "kernels": ALL_KERNELS,
        "roofline": [_roofline_rows],
    })


def main() -> None:
    _register_suites()
    which = sys.argv[1:] or ["paper", "kernels", "roofline"]
    for w in which:
        if w not in SUITES:
            print(f"unknown suite {w}; choices: {sorted(SUITES)}", file=sys.stderr)
            sys.exit(2)
    print("name,us_per_call,derived")
    failed = False
    for suite in which:
        rows: list[tuple] = []
        errors = 0
        for fn in SUITES[suite]:
            try:
                for row in fn():
                    name, us, derived = row[0], row[1], row[2]
                    print(f"{name},{us:.1f},{derived}")
                    rows.append(row)
            except Exception:
                failed = True
                errors += 1
                print(f"{fn.__name__},NaN,ERROR", flush=True)
                traceback.print_exc()
        write_artifact(suite, rows, extra={"errors": errors})
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
