"""Observability benchmark: telemetry overhead gate + trace/convergence smoke.

Telemetry that distorts what it measures is worse than none, so this bench
holds ``repro.obs`` to three promises:

1. **Overhead** -- the progressive-query hot path (store-backed p50 at a
   target relative error) is timed best-of-N with telemetry fully off and
   again with metrics + tracing enabled at ``sample_rate=1.0``.  The
   enabled/disabled ratio is the headline number; ``--smoke`` fails if the
   overhead exceeds 5%.

2. **Trace integrity** -- a concurrent serve workload (progressive
   quantile queries with deadlines over one shared executor) runs with
   tracing on; the Chrome trace exported to
   ``results/bench/TRACE_serve_smoke.json`` must be valid Perfetto input
   with spans from >= 3 distinct threads, every child span belonging to
   some query's trace -- cross-thread context propagation, witnessed.

3. **Convergence honesty** -- ``explain=True`` query traces must record
   strictly increasing block counts, and the last step's half-widths must
   equal the final result's CI half-widths exactly: the trace is the
   computation's own numbers, not a reconstruction.

Usage::

    PYTHONPATH=src python -m benchmarks.obs_bench            # full sizes
    PYTHONPATH=src python -m benchmarks.obs_bench --smoke    # CI gate
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys
import tempfile
import time

import numpy as np

from benchmarks.artifact import default_out_dir, write_artifact
from repro import obs, rsp

OVERHEAD_LIMIT = 0.05  # --smoke gate: enabled/disabled - 1 must stay below


def _build(num_blocks: int, block_records: int, features: int):
    rng = np.random.default_rng(0)
    n = num_blocks * block_records
    data = rng.lognormal(0.0, 1.0, size=(n, features)).astype(np.float32)
    return rsp.partition(data, blocks=num_blocks, seed=1)


def _time_progressive(path: str, *, repeats: int, target: float) -> float:
    """Best-of-``repeats`` seconds for a store-backed progressive p50 query.
    A fresh uncached dataset per repeat keeps the I/O identical across the
    off/on passes -- only the telemetry differs."""
    best = math.inf
    for _ in range(repeats):
        ds = rsp.open(path, cache_blocks=0)
        t0 = time.perf_counter()
        ds.query("median", target_rel_err=target, use_sketches=False, seed=7)
        best = min(best, time.perf_counter() - t0)
        ds.close()
    return best


def bench_overhead(
    *, num_blocks: int, block_records: int, features: int, repeats: int
) -> tuple[float, float, float]:
    """(seconds_off, seconds_on, overhead_fraction) for the progressive path."""
    ds = _build(num_blocks, block_records, features)
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "corpus.rsp")
        ds.save(path)
        ds.close()
        obs.disable()
        _time_progressive(path, repeats=1, target=0.02)  # warm compile caches
        t_off = _time_progressive(path, repeats=repeats, target=0.02)
        obs.enable(sample_rate=1.0)
        try:
            t_on = _time_progressive(path, repeats=repeats, target=0.02)
        finally:
            obs.disable()
    return t_off, t_on, t_on / max(t_off, 1e-12) - 1.0


def run_serve_smoke(
    *, num_blocks: int, block_records: int, features: int, queries: int,
    trace_path: str,
) -> dict:
    """Concurrent traced serve workload; exports the Chrome trace and returns
    the integrity report ``{"events", "threads", "query_spans", "orphans"}``."""
    obs.reset()
    obs.enable(sample_rate=1.0)
    try:
        ds = _build(num_blocks, block_records, features)
        with ds.serve(capacity=32, workers=4, seed=3) as svc:
            tickets = [
                svc.submit(
                    "median", target_rel_err=0.02, use_sketches=False,
                    deadline_ms=10_000,
                )
                for _ in range(queries)
            ]
            for t in tickets:
                svc.result(t)
        ds.close()
        os.makedirs(os.path.dirname(trace_path), exist_ok=True)
        obs.get_tracer().export_chrome(trace_path)
    finally:
        obs.disable()
    return validate_trace(trace_path)


def validate_trace(trace_path: str) -> dict:
    """Parse a Chrome trace and check span parenting across threads."""
    with open(trace_path) as f:
        payload = json.load(f)
    events = payload["traceEvents"]
    spans = [e for e in events if e.get("ph") == "X"]
    roots = [e for e in spans if e["name"] == "query"]
    root_traces = {e["args"]["trace_id"] for e in roots}
    children = [e for e in spans if "parent_id" in e["args"]]
    orphans = [e for e in children if e["args"]["trace_id"] not in root_traces]
    return {
        "events": len(spans),
        "threads": len({e["tid"] for e in spans}),
        "query_spans": len(roots),
        "children": len(children),
        "orphans": len(orphans),
        "names": sorted({e["name"] for e in spans}),
    }


def run_convergence_check(
    *, num_blocks: int, block_records: int, features: int
) -> dict:
    """``explain=True`` trace vs the final result it narrates."""
    ds = _build(num_blocks, block_records, features)
    res = ds.query("median", target_rel_err=0.03, use_sketches=False, seed=5, explain=True)
    ds.close()
    trace = res.trace
    assert trace is not None and len(trace) > 0, "explain=True produced no trace"
    blocks = trace.blocks
    monotone = all(b1 < b2 for b1, b2 in zip(blocks, blocks[1:]))
    last = trace.steps[-1]
    final_hw = {}
    for r in res.aggregates:
        if r.ci_lo is None or r.ci_hi is None:
            continue
        half = (np.asarray(r.ci_hi, dtype=float) - np.asarray(r.ci_lo, dtype=float)) / 2.0
        # mirror the trace's reduction: worst (max) half-width across features
        final_hw[r.name] = (
            float(np.nanmax(half)) if np.any(~np.isnan(half)) else math.nan
        )
    consistent = last.blocks_read == res.blocks_read and all(
        math.isclose(last.half_widths[k], v, rel_tol=1e-9, abs_tol=1e-12)
        or (math.isnan(last.half_widths[k]) and math.isnan(v))
        for k, v in final_hw.items()
    )
    return {
        "steps": len(trace),
        "blocks_read": res.blocks_read,
        "monotone": monotone,
        "consistent_with_final_ci": consistent,
        "final_rel_err": last.max_rel_err,
    }


def obs_rows(smoke: bool = False) -> list[tuple]:
    """``benchmarks.run``-style rows ``(name, value, derived, metrics)``."""
    if smoke:
        kw = dict(num_blocks=48, block_records=2304, features=8)
        repeats, queries = 5, 8
    else:
        kw = dict(num_blocks=96, block_records=9216, features=8)
        repeats, queries = 7, 16
    rows: list[tuple] = []

    t_off, t_on, overhead = bench_overhead(repeats=repeats, **kw)
    rows.append((
        "obs_overhead_progressive_p50",
        overhead * 100,
        f"off_ms={t_off * 1e3:.1f} on_ms={t_on * 1e3:.1f}"
        f" overhead={overhead:+.1%} limit={OVERHEAD_LIMIT:.0%}",
        {"seconds_off": t_off, "seconds_on": t_on, "overhead": overhead},
    ))

    trace_path = os.path.join(default_out_dir(), "TRACE_serve_smoke.json")
    report = run_serve_smoke(queries=queries, trace_path=trace_path, **kw)
    rows.append((
        "obs_serve_trace",
        report["events"],
        f"spans={report['events']} threads={report['threads']}"
        f" queries={report['query_spans']} orphans={report['orphans']}",
        report,
    ))

    conv = run_convergence_check(**kw)
    rows.append((
        "obs_convergence_trace",
        conv["steps"],
        f"steps={conv['steps']} blocks={conv['blocks_read']}"
        f" monotone={conv['monotone']}"
        f" ci_consistent={conv['consistent_with_final_ci']}",
        conv,
    ))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="small sizes + hard pass/fail gate")
    args = ap.parse_args()

    rows = obs_rows(smoke=args.smoke)
    print("name,value,derived")
    for row in rows:
        print(f"{row[0]},{row[1]:.1f},{row[2]}")
    write_artifact("obs", rows, extra={"smoke": args.smoke})

    if args.smoke:
        by_name = {row[0]: row[3] for row in rows}
        ok = True
        overhead = by_name["obs_overhead_progressive_p50"]["overhead"]
        if overhead > OVERHEAD_LIMIT:
            print(
                f"SMOKE FAIL: telemetry overhead {overhead:.1%} exceeds"
                f" {OVERHEAD_LIMIT:.0%} on the progressive query path",
                file=sys.stderr,
            )
            ok = False
        tr = by_name["obs_serve_trace"]
        if tr["threads"] < 3:
            print(
                f"SMOKE FAIL: serve trace has spans from only {tr['threads']}"
                " threads (< 3)", file=sys.stderr,
            )
            ok = False
        if tr["query_spans"] == 0 or tr["children"] == 0 or tr["orphans"]:
            print(
                f"SMOKE FAIL: trace parenting broken (queries={tr['query_spans']}"
                f" children={tr['children']} orphans={tr['orphans']})",
                file=sys.stderr,
            )
            ok = False
        conv = by_name["obs_convergence_trace"]
        if not (conv["monotone"] and conv["consistent_with_final_ci"]):
            print(
                f"SMOKE FAIL: convergence trace dishonest (monotone="
                f"{conv['monotone']} ci_consistent={conv['consistent_with_final_ci']})",
                file=sys.stderr,
            )
            ok = False
        if not ok:
            sys.exit(1)
        print(
            f"SMOKE OK: overhead {overhead:+.1%} <= {OVERHEAD_LIMIT:.0%};"
            f" trace spans {tr['events']} across {tr['threads']} threads,"
            f" 0 orphans; convergence trace monotone and CI-consistent"
        )


if __name__ == "__main__":
    main()
