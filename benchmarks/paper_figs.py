"""Benchmarks reproducing the paper's tables/figures on synthetic data.

One function per figure; each returns rows of (name, us_per_call, derived)
and the harness prints CSV.  Sizes are CPU-budgeted; the shapes of the
curves (linear partition scaling, fast estimator convergence, ensemble
plateau at a fraction of the data, block-batch time flatness) are the
reproduction targets, matched against the paper's claims in EXPERIMENTS.md.

The pipeline is driven through the ``repro.rsp`` facade with summary-sketch
computation disabled in timed regions so only Algorithm 1 / Algorithm 2 are
measured; the fig1 jax row and fig6/fig7 training timers deliberately use
the low-level substrate with pre-staged device arrays to keep timed regions
identical to prior runs (the facade adds host<->device copies).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import rsp
from repro.core import (
    BlockLevelEstimator,
    asymptotic_ensemble_learn,
    make_logreg,
    train_base_models_vmapped,
    two_stage_partition_jax,
)
from repro.data import make_higgs_like, make_nonrandom_higgs_like

Row = tuple[str, float, str]


def _timeit(fn, *args, repeat=3, **kw) -> float:
    fn(*args, **kw)  # warm
    t0 = time.perf_counter()
    for _ in range(repeat):
        fn(*args, **kw)
    return (time.perf_counter() - t0) / repeat * 1e6  # us


# ---------------------------------------------------------------------------
# Fig 1: partitioning time vs number of records (linear scaling)
# ---------------------------------------------------------------------------

def fig1_partition_scaling() -> list[Row]:
    rows: list[Row] = []
    F = 28
    times = {}
    for n in (50_000, 100_000, 200_000, 400_000):
        x, y = make_higgs_like(n, num_features=F, seed=0)
        data = np.concatenate([x, y[:, None].astype(np.float32)], axis=1)
        K = n // 10_000
        us = _timeit(
            lambda: rsp.partition(data, blocks=K, seed=1, backend="np", summaries=False),
            repeat=2,
        )
        times[n] = us
        rows.append((f"fig1_partition_np_n{n}", us, f"recs_per_s={n / (us / 1e6):.3e}"))
        # jax row: device-only timing of the registered backend's substrate
        # (excludes the facade's H2D/D2H copies so runs stay comparable)
        dj = jnp.asarray(data)
        fn = lambda: two_stage_partition_jax(
            dj, jax.random.PRNGKey(1), num_blocks=K, num_original_blocks=K
        ).block_until_ready()
        us_j = _timeit(fn, repeat=2)
        rows.append((f"fig1_partition_jax_n{n}", us_j, f"recs_per_s={n / (us_j / 1e6):.3e}"))
    # linearity: time(400k)/time(50k) should be ~8 (paper: "almost linear")
    ratio = times[400_000] / times[50_000]
    rows.append(("fig1_linearity_ratio_8x", 0.0, f"ratio={ratio:.2f}"))
    return rows


# ---------------------------------------------------------------------------
# Fig 2: probability distributions in RSP blocks vs whole data
# ---------------------------------------------------------------------------

def fig2_block_distributions() -> list[Row]:
    rows: list[Row] = []
    x, y = make_nonrandom_higgs_like(40_000, seed=3, class_sep=1.5)  # sorted = worst case
    data = np.concatenate([x, y[:, None].astype(np.float32)], axis=1)
    t0 = time.perf_counter()
    ds = rsp.partition(
        data, blocks=20, seed=2, backend="np", num_classes=2, summaries=False
    )
    part_us = (time.perf_counter() - t0) * 1e6  # Algorithm 1 only, no sketches
    rows.append(("fig2a_label_divergence_rsp_max", part_us, f"linf={ds.label_divergence():.4f}"))
    seq_div = _seq_chunk_divergence(data, y)
    rows.append(("fig2a_label_divergence_seq_chunk", 0.0, f"linf={seq_div:.4f}"))
    ks = max(ds.similarity(k, metric="ks", feature=0) for k in range(5))
    rows.append(("fig2b_feature_ks_rsp_max", 0.0, f"ks={ks:.4f}"))
    mmd = ds.similarity(0, metric="mmd", seed=0)
    rows.append(("fig2b_mmd_block_vs_data", 0.0, f"mmd2={mmd:.2e}"))
    return rows


def _seq_chunk_divergence(data: np.ndarray, y: np.ndarray) -> float:
    from repro.core.similarity import max_label_divergence

    return max_label_divergence(data[:2000, -1], y, 2)


# ---------------------------------------------------------------------------
# Figs 3/4: block-level estimation of means / stds
# ---------------------------------------------------------------------------

def fig34_estimation_convergence() -> list[Row]:
    rows: list[Row] = []
    rng = np.random.default_rng(5)
    data = (rng.normal(size=(100_000, 8)) * rng.uniform(0.5, 2, 8) + rng.normal(size=8)).astype(
        np.float32
    )
    ds = rsp.partition(data, blocks=100, seed=3, backend="np", summaries=False)
    true_mean, true_std = data.mean(0), data.std(0, ddof=1)
    est = BlockLevelEstimator()
    t0 = time.perf_counter()
    for g, k in enumerate(range(20), start=1):
        est.update(jnp.asarray(ds[k]))
        if g in (1, 5, 10, 20):
            em = float(np.abs(est.stats.mean - true_mean).max())
            es = float(np.abs(est.stats.std - true_std).max())
            rows.append((f"fig3_mean_abs_err_g{g}", 0.0, f"err={em:.5f}"))
            rows.append((f"fig4_std_abs_err_g{g}", 0.0, f"err={es:.5f}"))
    us = (time.perf_counter() - t0) * 1e6 / 20
    rows.append(("fig34_estimator_update", us, "per_block_update"))

    # the same estimate from partition-time sketches: time only the sketch
    # combine (the partition + sketch pass happens once, outside the timer)
    ds_sk = rsp.partition(data, blocks=100, seed=3, backend="np")
    t0 = time.perf_counter()
    sk = ds_sk.moments(g=20, seed=0)
    sk_us = (time.perf_counter() - t0) * 1e6
    rows.append((
        "fig34_sketch_moments_g20", sk_us,
        f"err={float(np.abs(sk.mean - true_mean).max()):.5f}",
    ))
    return rows


# ---------------------------------------------------------------------------
# Fig 6: asymptotic ensemble accuracy vs blocks used
# ---------------------------------------------------------------------------

def fig6_ensemble_accuracy() -> list[Row]:
    rows: list[Row] = []
    N, Ne, K = 40_000, 8_000, 40
    x, y = make_higgs_like(N + Ne, seed=2, class_sep=1.5)
    xe, ye = jnp.asarray(x[N:]), jnp.asarray(y[N:])
    data = np.concatenate([x[:N], y[:N, None].astype(np.float32)], axis=1)
    ds = rsp.partition(data, blocks=K, seed=5, backend="np", num_classes=2)
    learner = make_logreg(data.shape[1] - 1, 2, steps=200, lr=0.5)

    # pre-stage blocks on device (outside the timer, as prior runs did) so
    # ens_us measures Algorithm 2, not host<->device conversion
    blocks = ds.stacked()
    bx = jnp.asarray(blocks[:, :, :-1])
    by = jnp.asarray(blocks[:, :, -1].astype(np.int32))
    t0 = time.perf_counter()
    ens, hist = asymptotic_ensemble_learn(
        bx, by, learner=learner, eval_x=xe, eval_y=ye, g=5, seed=0,
        improvement_tol=5e-4, patience=2,
    )
    ens_us = (time.perf_counter() - t0) * 1e6
    for used, acc in zip(hist.blocks_used, hist.accuracy):
        rows.append((f"fig6_ensemble_acc_blocks{used}", 0.0, f"acc={acc:.4f}"))

    t0 = time.perf_counter()
    params = learner.fit(
        learner.init(jax.random.PRNGKey(1)),
        jnp.asarray(data[:, :-1]), jnp.asarray(data[:, -1].astype(np.int32)),
    )
    jax.block_until_ready(params)
    single_us = (time.perf_counter() - t0) * 1e6
    acc_single = float(
        (jnp.argmax(learner.predict_proba(params, xe), -1) == ye).mean()
    )
    rows.append(("fig6_single_full_data_model", single_us, f"acc={acc_single:.4f}"))
    rows.append((
        "fig6_summary", ens_us,
        f"ens_acc={hist.accuracy[-1]:.4f} single_acc={acc_single:.4f} "
        f"blocks_used={ens.num_models}/{K}",
    ))
    return rows


# ---------------------------------------------------------------------------
# Fig 7: training time, block batches vs whole data
# ---------------------------------------------------------------------------

def fig7_training_time() -> list[Row]:
    rows: list[Row] = []
    N, K = 80_000, 40
    x, y = make_higgs_like(N, seed=7, class_sep=1.5)
    data = np.concatenate([x, y[:, None].astype(np.float32)], axis=1)
    ds = rsp.partition(data, blocks=K, seed=5, backend="np", summaries=False)
    blocks = ds.stacked()
    bx = jnp.asarray(blocks[:, :, :-1])
    by = jnp.asarray(blocks[:, :, -1].astype(np.int32))
    learner = make_logreg(bx.shape[-1], 2, steps=200, lr=0.5)
    key = jax.random.PRNGKey(0)

    base_time = None
    for g in (2, 5, 10, 20):
        fn = lambda: jax.block_until_ready(
            train_base_models_vmapped(learner, key, bx[:g], by[:g])
        )
        us = _timeit(fn, repeat=2)
        if g == 2:
            base_time = us
        rows.append((f"fig7_block_batch_g{g}", us, f"pct_data={100 * g / K:.0f}%"))
    fn_full = lambda: jax.block_until_ready(
        learner.fit(
            learner.init(key),
            jnp.asarray(data[:, :-1]), jnp.asarray(data[:, -1].astype(np.int32)),
        )
    )
    full_us = _timeit(fn_full, repeat=2)
    rows.append((
        "fig7_single_model_all_data", full_us,
        f"vs_5pct_batch_ratio={full_us / base_time:.2f}",
    ))
    return rows


ALL_FIGS = [
    fig1_partition_scaling,
    fig2_block_distributions,
    fig34_estimation_convergence,
    fig6_ensemble_accuracy,
    fig7_training_time,
]
