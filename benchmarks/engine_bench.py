"""Streaming-engine benchmark: fetch-path throughput + sketch-guided selection.

Two measurements, mirroring what ``repro.rsp.engine`` is for:

1. **Fetch paths** -- records/sec for block-level estimation over a
   store-backed corpus through the three fetch paths: synchronous loads
   (``prefetch=0``), the prefetch pipeline, and memory-mapped streaming.
   The store fetcher is additionally wrapped with an emulated per-block
   I/O latency (``--latency``, default 8 ms) modelling the paper's setting
   of blocks served by a cluster file system rather than a warm local page
   cache; raw local-disk numbers are reported alongside.

2. **Selection policies** -- moment-estimation error vs. ``g`` for uniform
   block selection against sketch-weighted (HT-reweighted) selection on a
   *skewed, contiguously-chunked* corpus -- the non-RSP layout where
   uniform block sampling is at its worst and summary-statistics-driven
   selection (Rong et al., 2020) pays off.

Usage::

    PYTHONPATH=src python -m benchmarks.engine_bench            # full sizes
    PYTHONPATH=src python -m benchmarks.engine_bench --smoke    # CI gate

``--smoke`` uses small sizes and exits non-zero unless (a) the prefetched
path is >= 1.5x the synchronous path and (b) weighted selection beats
uniform on the skewed corpus -- so perf-path regressions fail loudly.
"""

from __future__ import annotations

import argparse
import os
import sys
import tempfile
import time

import numpy as np

from repro.core.registry import RSPStore
from repro.core.sampler import UniformPolicy, WeightedPolicy
from repro.core.types import RSPSpec
from repro.rsp.engine import BlockExecutor, MmapFetcher, StoreFetcher
from repro.rsp.summaries import combine_summaries, summarize_block, summarize_blocks


class LatencyFetcher:
    """Emulates remote-store latency: ``delay`` seconds per block fetch on
    top of the wrapped fetcher (sleeps release the GIL, like real I/O)."""

    def __init__(self, inner, delay: float):
        self.inner = inner
        self.delay = delay

    @property
    def num_blocks(self) -> int:
        return self.inner.num_blocks

    def fetch(self, block_id: int) -> np.ndarray:
        time.sleep(self.delay)
        return self.inner.fetch(block_id)


def _build_store(root: str, num_blocks: int, block_records: int, features: int) -> RSPStore:
    rng = np.random.default_rng(0)
    n = num_blocks * block_records
    data = rng.normal(size=(n, features)).astype(np.float32)
    spec = RSPSpec(
        num_records=n,
        num_blocks=num_blocks,
        num_original_blocks=1,  # layout metadata only; the bench writes blocks directly
        record_shape=(features,),
        dtype="float32",
    )
    store = RSPStore(root)
    store.write_partition(data.reshape(num_blocks, block_records, features), spec)
    return store


def _estimate(executor: BlockExecutor, num_blocks: int) -> None:
    """One full estimation sweep: sketch every block (``fn`` runs on the
    executor's workers, overlapping fetch and compute) and combine.
    Moments only -- this bench gates the engine's fetch/compute overlap,
    and the full suite's KLL/KMV folding would drown the fetch latency the
    prefetch pipeline is hiding."""
    sketches = executor.map_blocks(
        lambda b: summarize_block(b, 0, kinds=("moments",)), range(num_blocks)
    )
    combine_summaries(list(sketches))


def _throughput(executor: BlockExecutor, num_blocks: int, block_records: int) -> float:
    """records/sec for a full block-level estimation sweep."""
    t0 = time.perf_counter()
    _estimate(executor, num_blocks)
    return num_blocks * block_records / (time.perf_counter() - t0)


def bench_fetch_paths(
    *,
    num_blocks: int,
    block_records: int,
    features: int,
    latency: float,
    prefetch: int,
) -> dict[str, float]:
    out: dict[str, float] = {}
    with tempfile.TemporaryDirectory() as tmp:
        store = _build_store(os.path.join(tmp, "corpus"), num_blocks, block_records, features)
        # warm once so page-cache effects hit every path equally
        _estimate(BlockExecutor(StoreFetcher(store), prefetch=0), num_blocks)

        paths = {
            "sync": BlockExecutor(
                LatencyFetcher(StoreFetcher(store), latency), prefetch=0, cache_blocks=0
            ),
            "prefetch": BlockExecutor(
                LatencyFetcher(StoreFetcher(store), latency),
                prefetch=prefetch,
                cache_blocks=0,
            ),
            "sync_local": BlockExecutor(StoreFetcher(store), prefetch=0, cache_blocks=0),
            "prefetch_local": BlockExecutor(
                StoreFetcher(store), prefetch=prefetch, cache_blocks=0
            ),
            "mmap": BlockExecutor(MmapFetcher(store), prefetch=prefetch, cache_blocks=0),
        }
        for name, executor in paths.items():
            with executor:
                out[name] = _throughput(executor, num_blocks, block_records)
    return out


def bench_selection(
    *, num_blocks: int, block_records: int, gs: tuple[int, ...], trials: int
) -> list[tuple[int, float, float]]:
    """(g, uniform mean-abs-err, weighted mean-abs-err) on a skewed,
    contiguously chunked (non-RSP) corpus."""
    rng = np.random.default_rng(7)
    n = num_blocks * block_records
    x = np.sort(rng.lognormal(mean=1.0, sigma=1.2, size=n))
    blocks = x.reshape(num_blocks, block_records, 1)
    sketches = summarize_blocks(blocks)
    truth = x.mean()
    rows = []
    for g in gs:
        uni, wgt = [], []
        for s in range(trials):
            up = UniformPolicy(num_blocks, seed=s)
            ids = up.sample(g)
            uni.append(abs(combine_summaries([sketches[k] for k in ids]).mean[0] - truth))
            wp = WeightedPolicy(num_blocks, sketches, seed=s)
            ids = wp.sample(g)
            est = combine_summaries(
                [sketches[k] for k in ids], weights=wp.weights(ids), total_count=n
            ).mean[0]
            wgt.append(abs(est - truth))
        rows.append((g, float(np.mean(uni)), float(np.mean(wgt))))
    return rows


def engine_rows(smoke: bool = False, latency: float = 8e-3) -> list[tuple[str, float, str]]:
    """``benchmarks.run``-style rows: (name, value, derived)."""
    if smoke:
        fetch_kw = dict(num_blocks=32, block_records=8192, features=32)
        sel_kw = dict(num_blocks=32, block_records=128, gs=(4, 8), trials=60)
    else:
        fetch_kw = dict(num_blocks=96, block_records=16384, features=32)
        sel_kw = dict(num_blocks=64, block_records=1024, gs=(2, 4, 8, 16), trials=200)
    rows: list[tuple[str, float, str]] = []
    tp = bench_fetch_paths(latency=latency, prefetch=4, **fetch_kw)
    speedup = tp["prefetch"] / tp["sync"]
    for name, rps in tp.items():
        derived = f"records_per_s={rps:,.0f}"
        if name == "prefetch":
            derived += f" speedup_vs_sync={speedup:.2f}x"
        rows.append((f"engine_fetch_{name}", rps, derived))
    for g, uerr, werr in bench_selection(**sel_kw):
        # row value is the uniform/weighted error ratio (>1 == weighted wins):
        # it stays legible under the harness's fixed-point value formatting,
        # unlike the raw ~1e-2 error magnitudes kept in the derived column
        rows.append(
            (
                f"engine_policy_g{g}",
                uerr / max(werr, 1e-12),
                f"uniform_err={uerr:.4f} weighted_err={werr:.4f} "
                f"ratio={uerr / max(werr, 1e-12):.2f}x",
            )
        )
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="small sizes + hard pass/fail gate")
    ap.add_argument("--latency", type=float, default=8e-3,
                    help="emulated per-block store latency in seconds (default 8ms)")
    args = ap.parse_args()

    rows = engine_rows(smoke=args.smoke, latency=args.latency)
    print("name,value,derived")
    for name, value, derived in rows:
        print(f"{name},{value:.1f},{derived}")

    if args.smoke:
        by_name = {name: (value, derived) for name, value, derived in rows}
        speedup = by_name["engine_fetch_prefetch"][0] / by_name["engine_fetch_sync"][0]
        policy_rows = [(n, d) for n, (v, d) in by_name.items() if n.startswith("engine_policy")]
        weighted_wins = all(
            float(d.split("ratio=")[1].rstrip("x")) > 1.0 for _, d in policy_rows
        )
        ok = True
        if speedup < 1.5:
            print(f"SMOKE FAIL: prefetch speedup {speedup:.2f}x < 1.5x", file=sys.stderr)
            ok = False
        if not weighted_wins:
            print("SMOKE FAIL: weighted selection did not beat uniform", file=sys.stderr)
            ok = False
        if not ok:
            sys.exit(1)
        print(f"SMOKE OK: prefetch {speedup:.2f}x, weighted beats uniform at all g")


if __name__ == "__main__":
    main()
