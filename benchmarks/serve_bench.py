"""Concurrent query-serving benchmark: thousands of progressive queries over
one shared executor, with admission control and anytime deadlines.

Four tenant classes are interleaved against one store-backed RSP dataset
through ``RSPDataset.serve()``:

* **sketch** -- moment/count queries answered from the partition-time
  sketches (the zero-I/O fast path; never queued, never scheduled);
* **converged** -- median queries with an achievable target relative error
  (the progressive bread-and-butter: read a few blocks, stop early);
* **truncated** -- mean queries capped at ``max_blocks=4``: they exhaust
  their block budget without converging, so their answer is an *anytime*
  estimate whose CI must cover the full-scan answer;
* **deadline** -- mean queries chasing an unreachable target under a tight
  ``deadline_ms`` (PPS-with-replacement selection, so they can neither
  converge nor exhaust): the deadline is the only way out, and the service
  must return their current anytime estimate at it.

Reported rows: service QPS + latency percentiles, shared-cache hit rate vs
an isolated-executor baseline (same query mix, one fresh executor per
query), sketch fast-path latency, anytime CI coverage, and admission
behavior.  ``results/bench/BENCH_serve.json`` is written on every run.

Usage::

    PYTHONPATH=src python -m benchmarks.serve_bench            # full sizes
    PYTHONPATH=src python -m benchmarks.serve_bench --smoke    # CI gate

``--smoke`` runs >= 1000 concurrent progressive queries and exits non-zero
unless: sketch-only queries fetch exactly 0 blocks; the shared cache's hit
rate is strictly above the isolated baseline; every class's p99 latency is
within its deadline budget (+ a fixed enforcement slack); every anytime
(truncated / deadline) result's CI covers the full-scan answer; and at
least one deadline result carries a real partial estimate (>= 1 block).
Anytime classes use mean aggregates at 99.9999% confidence on purpose:
across hundreds of queries x 8 features of jointly gated intervals, only a
far-tail confidence makes "every CI covers" a correctness property rather
than a coin flip (nominal 95% intervals *should* miss ~5% of the time).
"""

from __future__ import annotations

import argparse
import math
import os
import sys
import tempfile
import time

import numpy as np

from benchmarks.artifact import write_artifact
from repro import rsp
from repro.rsp.query import derive_seed
from repro.serve import AdmissionRejected

# latency slack added on top of a query's deadline budget before the p99
# gate trips: deadline enforcement is exact by construction (worker pre-step
# checks + result() waiters), the slack only absorbs host scheduling jitter
SLACK_MS = 250.0


def _build(tmp: str, *, num_blocks: int, block_records: int, features: int):
    rng = np.random.default_rng(0)
    n = num_blocks * block_records
    data = rng.normal(5.0, 1.0, size=(n, features)).astype(np.float32)
    ds = rsp.partition(data, blocks=num_blocks, seed=1)
    path = os.path.join(tmp, "corpus.rsp")
    ds.save(path)
    ds.close()
    return path, data


def _plan(counts: dict[str, int]) -> list[str]:
    """Round-robin interleave of the tenant classes (multi-tenant mix, not
    class-by-class waves -- admission sees all classes competing at once)."""
    pools = {c: n for c, n in counts.items()}
    out: list[str] = []
    while any(pools.values()):
        for c in counts:
            if pools[c] > 0:
                pools[c] -= 1
                out.append(c)
    return out


def _submit(svc, cls: str, *, tight_ms: float, wide_ms: float):
    if cls == "sketch":
        return svc.submit(["mean", "var", "count"], deadline_ms=wide_ms)
    if cls == "converged":
        return svc.submit(
            "median", target_rel_err=0.05, use_sketches=False, deadline_ms=wide_ms
        )
    if cls == "truncated":
        return svc.submit(
            "mean", use_sketches=False, max_blocks=4, confidence=0.999999,
            deadline_ms=wide_ms,
        )
    if cls == "deadline":
        return svc.submit(
            "mean", use_sketches=False, target_rel_err=1e-12, policy="weighted",
            max_blocks=10**7, confidence=0.999999, deadline_ms=tight_ms,
        )
    raise ValueError(cls)


def _covers(agg, truth: np.ndarray) -> bool:
    lo = -math.inf if agg.ci_lo is None else np.asarray(agg.ci_lo, np.float64)
    hi = math.inf if agg.ci_hi is None else np.asarray(agg.ci_hi, np.float64)
    return bool(np.all(lo <= truth) and np.all(truth <= hi))


def _p99(latencies_ms: list[float]) -> float:
    if not latencies_ms:
        return math.nan
    s = sorted(latencies_ms)
    return s[min(len(s) - 1, max(0, math.ceil(0.99 * len(s)) - 1))]


def _isolated_hit_rate(path: str, *, cache_blocks: int, n: int) -> float:
    """The no-sharing baseline: the same progressive query mix, each query on
    its own freshly opened dataset (private executor + private cache).  Only
    a query's *own* re-picks (the with-replacement class) can hit."""
    total = rsp.ExecutorStats()
    for i in range(n):
        ds = rsp.open(path, cache_blocks=cache_blocks)
        seed = derive_seed(7, i)
        if i % 3 == 0:
            ds.query(
                "mean", use_sketches=False, target_rel_err=1e-12,
                policy="weighted", max_blocks=12, seed=seed,
            )
        elif i % 3 == 1:
            ds.query("median", target_rel_err=0.05, use_sketches=False, seed=seed)
        else:
            ds.query("mean", use_sketches=False, max_blocks=4, seed=seed)
        total = total + ds.executor.stats()
        ds.close()
    return total.hit_rate


def _bench_reject(path: str) -> int:
    """Deterministic saturation scenario: capacity 1, no queue -> the second
    concurrent progressive query must be rejected, not silently queued."""
    ds = rsp.open(path, cache_blocks=4)
    rejected = 0
    with ds.serve(capacity=1, max_queue=0, workers=1, seed=9) as svc:
        hog = svc.submit(
            "mean", use_sketches=False, target_rel_err=1e-12,
            policy="weighted", max_blocks=10**7,
        )
        try:
            svc.submit("median", use_sketches=False)
        except AdmissionRejected:
            rejected += 1
        svc.cancel(hog)
    ds.close()
    return rejected


def serve_bench(smoke: bool = False):
    """Run the serving workload; returns (rows, gates) where ``gates`` holds
    everything the smoke verdict needs."""
    if smoke:
        shape = dict(num_blocks=64, block_records=1024, features=8)
        counts = {"sketch": 256, "converged": 640, "truncated": 200, "deadline": 200}
        capacity, workers, cache_blocks = 64, 8, 64
        iso_n = 30
    else:
        shape = dict(num_blocks=128, block_records=2048, features=8)
        counts = {"sketch": 500, "converged": 1200, "truncated": 300, "deadline": 300}
        capacity, workers, cache_blocks = 128, 8, 128
        iso_n = 45
    tight_ms, wide_ms = 1200.0, 10_000.0

    with tempfile.TemporaryDirectory() as tmp:
        path, data = _build(tmp, **shape)
        truth = data.astype(np.float64).mean(axis=0)

        ds = rsp.open(path, cache_blocks=cache_blocks)
        t0 = time.perf_counter()
        with ds.serve(capacity=capacity, workers=workers, seed=7) as svc:
            tickets = [
                (cls, _submit(svc, cls, tight_ms=tight_ms, wide_ms=wide_ms))
                for cls in _plan(counts)
            ]
            results = [(cls, t, svc.result(t, timeout=120)) for cls, t in tickets]
            metrics = svc.metrics()
        wall_s = time.perf_counter() - t0
        ds.close()

        shared_rate = metrics.cache_hit_rate
        isolated_rate = _isolated_hit_rate(path, cache_blocks=cache_blocks, n=iso_n)
        rejected_when_full = _bench_reject(path)

    by_cls: dict[str, list] = {c: [] for c in counts}
    for cls, t, res in results:
        by_cls[cls].append((t, res))

    sketch_lat = [t.latency_ms for t, _ in by_cls["sketch"]]
    sketch_io = max(r.executor_stats.blocks_fetched for _, r in by_cls["sketch"])
    anytime = [(t, r) for c in ("truncated", "deadline") for t, r in by_cls[c]]
    covered = sum(_covers(r["mean"], truth) for _, r in anytime)
    deadline_blocks = [r.blocks_read for _, r in by_cls["deadline"]]
    p99_by_cls = {c: _p99([t.latency_ms for t, _ in by_cls[c]]) for c in counts}
    budget = {c: (tight_ms if c == "deadline" else wide_ms) for c in counts}
    progressive = sum(n for c, n in counts.items() if c != "sketch")
    conv_frac = sum(
        t.outcome in ("converged", "exhausted") for t, _ in by_cls["converged"]
    ) / counts["converged"]

    fetched_rows_per_s = metrics.executor.rows_fetched / max(wall_s, 1e-9)
    scanned_rows_per_s = (
        metrics.executor.accesses * shape["block_records"] / max(wall_s, 1e-9)
    )
    rows = [
        (
            "serve_throughput",
            metrics.qps,
            f"queries={metrics.submitted} progressive={progressive}"
            f" wall_s={wall_s:.2f} p50_ms={metrics.latency_p50_ms:.1f}"
            f" p99_ms={metrics.latency_p99_ms:.1f}"
            f" rows_per_s={scanned_rows_per_s:,.0f}",
            # scanned = every block pass (cache hits included); fetched =
            # rows that actually crossed the fetcher (cache misses)
            {"rows_per_s": scanned_rows_per_s,
             "fetched_rows_per_s": fetched_rows_per_s},
        ),
        (
            "serve_cache_sharing",
            shared_rate,
            f"shared={shared_rate:.3f} isolated={isolated_rate:.3f}"
            f" hits={metrics.executor.hits} misses={metrics.executor.misses}",
            {"rows_fetched": metrics.executor.rows_fetched},
        ),
        (
            "serve_sketch_fast_path",
            float(np.mean(sketch_lat) * 1e3),
            f"us_per_query={np.mean(sketch_lat) * 1e3:.0f}"
            f" blocks_fetched={sketch_io} n={counts['sketch']}",
        ),
        (
            "serve_anytime",
            len(anytime),
            f"ci_covered={covered}/{len(anytime)}"
            f" deadline_hits={metrics.deadline_hits}"
            f" deadline_p99_ms={p99_by_cls['deadline']:.0f}"
            f" max_partial_blocks={max(deadline_blocks)}",
        ),
        (
            "serve_admission",
            float(metrics.admission.admitted_total),
            f"admitted={metrics.admission.admitted_total}"
            f" rejected_when_full={rejected_when_full}"
            f" converged_frac={conv_frac:.2f}"
            f" blocks_per_query={metrics.blocks_per_query:.2f}",
        ),
    ]
    gates = {
        "progressive_queries": progressive,
        "sketch_blocks_fetched": int(sketch_io),
        "shared_hit_rate": shared_rate,
        "isolated_hit_rate": isolated_rate,
        "anytime_total": len(anytime),
        "anytime_ci_covered": int(covered),
        "deadline_max_partial_blocks": int(max(deadline_blocks)),
        "rejected_when_full": rejected_when_full,
        "p99_ms_by_class": p99_by_cls,
        "deadline_budget_ms_by_class": budget,
        "slack_ms": SLACK_MS,
    }
    return rows, gates


def serve_rows(smoke: bool = False) -> list[tuple]:
    """``benchmarks.run``-style rows ``(name, value, derived[, metrics])``."""
    return serve_bench(smoke=smoke)[0]


def _verdict(gates: dict) -> list[str]:
    failures = []
    if gates["progressive_queries"] < 1000:
        failures.append(
            f"only {gates['progressive_queries']} concurrent progressive queries (< 1000)"
        )
    if gates["sketch_blocks_fetched"] != 0:
        failures.append(
            f"sketch-only queries fetched {gates['sketch_blocks_fetched']} blocks"
        )
    if not gates["shared_hit_rate"] > gates["isolated_hit_rate"]:
        failures.append(
            f"shared cache hit rate {gates['shared_hit_rate']:.3f} not above"
            f" isolated baseline {gates['isolated_hit_rate']:.3f}"
        )
    if gates["anytime_ci_covered"] != gates["anytime_total"]:
        failures.append(
            f"only {gates['anytime_ci_covered']}/{gates['anytime_total']}"
            f" anytime CIs cover the full-scan answer"
        )
    if gates["deadline_max_partial_blocks"] < 1:
        failures.append("no deadline query returned a partial (>= 1 block) estimate")
    if gates["rejected_when_full"] != 1:
        failures.append("saturated capacity-1/queue-0 service did not reject")
    for cls, p99 in gates["p99_ms_by_class"].items():
        cap = gates["deadline_budget_ms_by_class"][cls] + gates["slack_ms"]
        if not p99 <= cap:
            failures.append(f"{cls} p99 {p99:.0f}ms exceeds budget {cap:.0f}ms")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke", action="store_true", help="CI sizes + hard pass/fail gate"
    )
    args = ap.parse_args()

    rows, gates = serve_bench(smoke=args.smoke)
    print("name,value,derived")
    for row in rows:
        print(f"{row[0]},{row[1]:.1f},{row[2]}")
    path = write_artifact("serve", rows, extra={"gates": gates, "smoke": args.smoke})
    print(f"wrote {path}")

    if args.smoke:
        failures = _verdict(gates)
        for msg in failures:
            print(f"SMOKE FAIL: {msg}", file=sys.stderr)
        if failures:
            sys.exit(1)
        print(
            f"SMOKE OK: {gates['progressive_queries']} progressive queries;"
            f" shared hit rate {gates['shared_hit_rate']:.3f} >"
            f" isolated {gates['isolated_hit_rate']:.3f}; sketch I/O 0;"
            f" {gates['anytime_ci_covered']}/{gates['anytime_total']}"
            f" anytime CIs cover; per-class p99 within budget"
        )


if __name__ == "__main__":
    main()
