"""End-to-end LM training on an RSP token corpus with checkpoint/restart.

The corpus is partitioned into RSP blocks of sequences; the training loader
consumes block-level samples, so every global batch is a random sample of
the corpus with no run-time shuffle and an O(1)-byte data-pipeline
checkpoint.  Mid-run the script simulates a preemption and restarts from
the latest checkpoint.

Presets:
    cpu-small (default): ~7M-param llama-style model, runs in minutes on CPU
    100m: ~115M params, seq 1024 -- the "train ~100M for a few hundred
          steps" driver for real hardware (works on CPU too, just slowly)

    PYTHONPATH=src python examples/train_lm_rsp.py --steps 60
"""

import argparse
import dataclasses
import tempfile

import jax.numpy as jnp

from repro import rsp
from repro.configs import ARCHS
from repro.data.synthetic import make_token_corpus
from repro.optim import AdamWConfig
from repro.train import TrainConfig, Trainer

PRESETS = {
    "cpu-small": dict(num_layers=4, d_model=256, num_heads=8, num_kv_heads=4,
                      d_ff=1024, vocab_size=2048, seq=128, batch=8),
    "100m": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
                 d_ff=3072, vocab_size=32000, seq=1024, batch=32),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=sorted(PRESETS), default="cpu-small")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--preempt-at", type=int, default=None,
                    help="simulate preemption after N steps, then restart")
    ap.add_argument("--arch", default="llama3.2-1b", choices=sorted(ARCHS))
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = dataclasses.replace(
        ARCHS[args.arch],
        num_layers=p["num_layers"], d_model=p["d_model"], num_heads=p["num_heads"],
        num_kv_heads=p["num_kv_heads"], d_ff=p["d_ff"], vocab_size=p["vocab_size"],
        head_dim=0,
    )
    seq, batch = p["seq"], p["batch"]

    # --- corpus -> RSP blocks of sequences ---------------------------------
    n_seqs, K = 512, 16   # N/(P*K) must be integral: 512/(16*16) = 2
    corpus = make_token_corpus(n_seqs, seq + 1, vocab_size=cfg.vocab_size, seed=0, drift=True)
    # int token data: backend="auto" routes to the numpy streaming path
    ds = rsp.partition(corpus, blocks=K, seed=1, summaries=False)
    print(f"corpus: {n_seqs} sequences x {seq + 1} tokens -> {K} RSP blocks "
          f"(backend={ds.backend!r})")

    ckpt_dir = tempfile.mkdtemp(prefix="rsp_lm_ckpt_")
    tc = TrainConfig(total_steps=args.steps, warmup_steps=max(args.steps // 10, 1),
                     checkpoint_every=max(args.steps // 3, 1), log_every=5, seed=0)

    def make_trainer():
        return Trainer(
            cfg, AdamWConfig(lr=3e-3), tc,
            ds.loader(batch_size=batch, seed=5),
            ckpt_dir,
            batch_transform=lambda b: {"tokens": jnp.asarray(b, jnp.int32)},
        )

    preempt = args.preempt_at or args.steps // 2
    print(f"training {args.steps} steps; simulating preemption at {preempt}")
    t1 = make_trainer()
    t1.run(stop_after_steps=preempt)
    print(f"-- preempted; checkpoint saved; restarting fresh --")
    t2 = make_trainer()
    t2.run()
    for h in t1.history + t2.history:
        print(f"  step {h['step']:4d} loss {h['loss']:.4f} "
              f"({h['sec_per_step']:.2f}s/step)")
    first, last = t1.history[0]["loss"], t2.history[-1]["loss"]
    print(f"loss {first:.4f} -> {last:.4f} "
          f"({'OK' if last < first else 'NOT DECREASING'}); "
          f"restart resumed exactly from the checkpointed sampler state")


if __name__ == "__main__":
    main()
