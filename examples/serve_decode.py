"""Batched serving with KV-cache decode, plus RSP-ensemble serving.

Trains k tiny LMs on disjoint RSP block samples (Algorithm 2 applied to
language models), then serves batched requests from (a) a single model and
(b) the logit-averaged ensemble (Sec. 9's combination function at decode
time).

    PYTHONPATH=src python examples/serve_decode.py
"""

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.core import RSPSpec, two_stage_partition_np
from repro.core.sampler import BlockSampler
from repro.data.synthetic import make_token_corpus
from repro.models import api
from repro.models.common import init_params
from repro.optim import AdamWConfig
from repro.serve.engine import EnsembleServer, ServeConfig, Server
from repro.train import TrainConfig, init_state, make_train_step


def train_on_blocks(cfg, block_tokens, steps=30, seed=0):
    tc = TrainConfig(total_steps=steps, warmup_steps=3, seed=seed)
    step_fn = jax.jit(make_train_step(cfg, AdamWConfig(lr=5e-3), tc))
    state = init_state(cfg, seed)
    flat = block_tokens.reshape(-1, block_tokens.shape[-1])
    rng = np.random.default_rng(seed)
    for i in range(steps):
        idx = rng.integers(0, flat.shape[0], size=8)
        state, m = step_fn(state, {"tokens": jnp.asarray(flat[idx], jnp.int32)})
    return jax.tree.map(lambda a: a.astype(jnp.float32), state["params"]), float(m["loss"])


def main():
    cfg = dataclasses.replace(
        ARCHS["llama3.2-1b"],
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, d_ff=512,
        vocab_size=512, head_dim=0,
    )
    corpus = make_token_corpus(256, 33, vocab_size=cfg.vocab_size, seed=0)
    spec = RSPSpec(num_records=256, num_blocks=16, num_original_blocks=16, seed=1)
    blocks = two_stage_partition_np(corpus, spec)

    # k base models on disjoint block-level samples
    k = 3
    sampler = BlockSampler(16, seed=2)
    stacked = None
    for i in range(k):
        ids = sampler.sample(4)
        params, loss = train_on_blocks(cfg, blocks[np.asarray(ids)], seed=i)
        print(f"base model {i}: blocks {ids}, final loss {loss:.3f}")
        stacked = (jax.tree.map(lambda a: a[None], params) if stacked is None
                   else jax.tree.map(lambda s, p: jnp.concatenate([s, p[None]]), stacked, params))

    prompts = jnp.asarray(
        np.random.default_rng(9).integers(0, cfg.vocab_size, (4, 8), np.int32)
    )

    single = Server(cfg, jax.tree.map(lambda a: a[0], stacked), ServeConfig())
    t0 = time.time()
    out1 = single.generate(prompts, max_new_tokens=16)
    print(f"single-model batched decode: {out1.shape} in {time.time() - t0:.2f}s")
    print("  sample:", out1[0].tolist())

    ens = EnsembleServer(cfg, stacked, ServeConfig())
    t0 = time.time()
    out2 = ens.generate(prompts, max_new_tokens=16)
    print(f"RSP-ensemble ({k} models) batched decode: {out2.shape} in {time.time() - t0:.2f}s")
    print("  sample:", out2[0].tolist())


if __name__ == "__main__":
    main()
