"""End-to-end driver: asymptotic ensemble learning on a HIGGS-like corpus
(the paper's Fig. 6/7 experiment) with an on-disk RSP store, through the
``repro.rsp`` facade.

The corpus is materialized as an RSP once (``ds.save``); analysis then
touches only the sampled blocks -- including after a simulated node failure,
where the lost host's blocks are re-dealt to the survivors (Theorem 1).

    PYTHONPATH=src python examples/ensemble_higgs.py [--records 100000]
"""

import argparse
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import rsp
from repro.data import make_higgs_like


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--records", type=int, default=100_000)
    ap.add_argument("--blocks", type=int, default=50)
    ap.add_argument("--batch-blocks", type=int, default=5)
    ap.add_argument("--learner", choices=("logreg", "mlp"), default="logreg")
    args = ap.parse_args()

    N, K = args.records, args.blocks
    x, y = make_higgs_like(N + 10_000, seed=2, class_sep=1.5)
    xe, ye = jnp.asarray(x[N:]), jnp.asarray(y[N:])
    data = np.concatenate([x[:N], y[:N, None].astype(np.float32)], axis=1)

    # --- create + store the RSP (done once per corpus) ---------------------
    t0 = time.time()
    root = tempfile.mkdtemp(prefix="rsp_")
    rsp.partition(data, blocks=K, seed=1, num_classes=2).save(root)
    ds = rsp.open(root)   # lazy, store-backed from here on
    print(f"[partition+store] {N} records -> {K} blocks "
          f"(backend={ds.backend!r}) in {time.time() - t0:.2f}s at {root}")

    # --- deal blocks to 4 hosts, then lose one -----------------------------
    assign = ds.deal(num_hosts=4, seed=3).redistribute([2])
    print(f"[elastic] host 2 failed; survivors now hold "
          f"{[len(assign.blocks_for(h)) for h in (0, 1, 3)]} blocks")

    # --- Algorithm 2 over the stored RSP -----------------------------------
    F = x.shape[1]
    learner = (rsp.make_logreg(F, 2, steps=200, lr=0.5) if args.learner == "logreg"
               else rsp.make_mlp(F, 2, hidden=32, steps=300, lr=0.05))
    t0 = time.time()
    ensemble, hist = ds.ensemble(
        learner, eval_x=xe, eval_y=ye, g=args.batch_blocks, seed=9,
        improvement_tol=1e-3, patience=2,
    )
    ens_s = time.time() - t0
    for used, acc in zip(hist.blocks_used, hist.accuracy):
        print(f"  ensemble acc {acc:.4f} ({used}/{K} blocks)")
    print(f"[ensemble] trained in {ens_s:.1f}s, loading only sampled blocks")
    best = max(hist.accuracy)

    # --- the full-data single model for comparison (Fig. 6 dotted line) ----
    t0 = time.time()
    params = learner.fit(
        learner.init(jax.random.PRNGKey(1)),
        jnp.asarray(data[:, :-1]), jnp.asarray(data[:, -1].astype(np.int32)),
    )
    jax.block_until_ready(params)
    single = float((jnp.argmax(learner.predict_proba(params, xe), -1) == ye).mean())
    print(f"[single model, all data] acc {single:.4f} in {time.time() - t0:.1f}s")
    print(f"[result] ensemble {best:.4f} vs single {single:.4f} "
          f"using {ensemble.num_models}/{K} blocks")


if __name__ == "__main__":
    main()
