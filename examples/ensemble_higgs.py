"""End-to-end driver: asymptotic ensemble learning on a HIGGS-like corpus
(the paper's Fig. 6/7 experiment) with an on-disk RSP store.

The corpus is materialized as an RSP once; analysis then touches only the
sampled blocks -- including after a simulated node failure, where the lost
host's blocks are re-dealt to the survivors (Theorem 1).

    PYTHONPATH=src python examples/ensemble_higgs.py [--records 100000]
"""

import argparse
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    Ensemble,
    RSPSpec,
    RSPStore,
    make_logreg,
    make_mlp,
    train_base_models_vmapped,
    two_stage_partition_np,
)
from repro.core.sampler import BlockSampler, deal_blocks
from repro.data import make_higgs_like


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--records", type=int, default=100_000)
    ap.add_argument("--blocks", type=int, default=50)
    ap.add_argument("--batch-blocks", type=int, default=5)
    ap.add_argument("--learner", choices=("logreg", "mlp"), default="logreg")
    args = ap.parse_args()

    N, K = args.records, args.blocks
    x, y = make_higgs_like(N + 10_000, seed=2, class_sep=1.5)
    xe, ye = jnp.asarray(x[N:]), jnp.asarray(y[N:])
    data = np.concatenate([x[:N], y[:N, None].astype(np.float32)], axis=1)

    # --- create + store the RSP (done once per corpus) ---------------------
    t0 = time.time()
    spec = RSPSpec(num_records=N, num_blocks=K, num_original_blocks=K, seed=1)
    blocks = two_stage_partition_np(data, spec)
    store = RSPStore(tempfile.mkdtemp(prefix="rsp_"))
    store.write_partition(blocks, spec)
    print(f"[partition+store] {N} records -> {K} blocks in {time.time() - t0:.2f}s "
          f"at {store.root}")

    # --- deal blocks to 4 hosts, then lose one -----------------------------
    assign = deal_blocks(K, num_hosts=4, seed=3)
    assign = assign.redistribute([2])
    print(f"[elastic] host 2 failed; survivors now hold "
          f"{[len(assign.blocks_for(h)) for h in (0, 1, 3)]} blocks")

    # --- Algorithm 2 over the stored RSP -----------------------------------
    F = x.shape[1]
    learner = (make_logreg(F, 2, steps=200, lr=0.5) if args.learner == "logreg"
               else make_mlp(F, 2, hidden=32, steps=300, lr=0.05))
    sampler = BlockSampler(K, seed=9)
    ensemble = Ensemble(learner)
    key = jax.random.PRNGKey(0)
    best, stall = 0.0, 0
    t0 = time.time()
    while sampler.remaining_in_epoch() > 0 and stall < 2:
        ids = sampler.sample(min(args.batch_blocks, sampler.remaining_in_epoch()))
        batch = store.load_blocks(ids)
        bx = jnp.asarray(batch[:, :, :-1])
        by = jnp.asarray(batch[:, :, -1].astype(np.int32))
        key, sub = jax.random.split(key)
        params = train_base_models_vmapped(learner, sub, bx, by)
        ensemble.add_stacked(params, len(ids))
        acc = ensemble.accuracy(xe, ye)
        print(f"  batch {ids} -> ensemble acc {acc:.4f} "
              f"({ensemble.num_models}/{K} blocks, {time.time() - t0:.1f}s)")
        stall = stall + 1 if acc - best < 1e-3 else 0
        best = max(best, acc)

    # --- the full-data single model for comparison (Fig. 6 dotted line) ----
    t0 = time.time()
    params = learner.fit(
        learner.init(jax.random.PRNGKey(1)),
        jnp.asarray(data[:, :-1]), jnp.asarray(data[:, -1].astype(np.int32)),
    )
    jax.block_until_ready(params)
    single = float((jnp.argmax(learner.predict_proba(params, xe), -1) == ye).mean())
    print(f"[single model, all data] acc {single:.4f} in {time.time() - t0:.1f}s")
    print(f"[result] ensemble {best:.4f} vs single {single:.4f} "
          f"using {ensemble.num_models}/{K} blocks")


if __name__ == "__main__":
    main()
