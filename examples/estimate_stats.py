"""Block-level statistics estimation (paper Sec. 8, Figs. 3/4) through the
``repro.rsp`` facade: watch the estimates converge to the full-data truth as
blocks are added, with the plateau detector stopping the scan early.

    PYTHONPATH=src python examples/estimate_stats.py
"""

import numpy as np

from repro import rsp
from repro.core import block_histogram, quantile_from_histogram
from repro.data import make_higgs_like


def main():
    N, K = 200_000, 100
    x, y = make_higgs_like(N, seed=4)
    data = np.concatenate([x, y[:, None].astype(np.float32)], axis=1)
    ds = rsp.partition(data, blocks=K, seed=7, num_classes=2)
    truth_mean = data.mean(0)
    truth_std = data.std(0, ddof=1)

    # streaming fold over a block-level sample, with convergence history
    est = rsp.BlockLevelEstimator()
    order = ds.sample(K, seed=0)
    print("blocks  max|mean err|  max|std err|  converged?")
    for g, k in enumerate(order, start=1):
        est.update(ds[k])
        conv = est.converged(rel_tol=1e-3)
        if g in (1, 2, 5, 10, 20) or conv:
            em = np.abs(est.stats.mean - truth_mean).max()
            es = np.abs(est.stats.std - truth_std).max()
            print(f"{g:6d}  {em:13.6f}  {es:12.6f}  {conv}")
        if conv:
            print(f"-> plateau after {g}/{K} blocks ({100 * g / K:.0f}% of the data)")
            break

    # the same estimate from the partition-time sketches: no block reads at all
    sk = ds.moments(g=20, seed=0)
    print(f"sketch-only moments from 20 blocks: "
          f"max|mean err| {np.abs(sk.mean - truth_mean).max():.6f}")

    # distribution-level checks on one block (Sec. 7 toolkit)
    mmd = ds.similarity(3, metric="mmd", seed=0)
    ks = ds.similarity(3, metric="ks", feature=0)
    print(f"block 3 vs data: MMD^2={mmd:.2e}, KS={ks:.4f}")

    # quantiles via combinable histograms
    h = sum(block_histogram(ds[k], bins=256, lo=-8, hi=8) for k in range(5))
    q = quantile_from_histogram(h, [0.5], lo=-8, hi=8)[:, 0]
    true_q = np.quantile(data, 0.5, axis=0)
    print(f"median from 5 blocks: max abs err {np.abs(q - true_q).max():.4f}")

    # progressive declarative query (repro.rsp.query): ask for feature 0's
    # 90th percentile at 2% relative error and watch the anytime CI narrow
    # block by block until the stopping rule fires -- the paper's "few
    # blocks" loop made explicit.  (Relative-error stopping needs a
    # statistic away from zero; p90 here, unlike the near-zero medians.)
    print("\nprogressive query: p90[feature 0] @ 2% target relative error")
    print("blocks  p90[0]      95% CI            rel_err")
    for res in ds.query_stream(
        rsp.Aggregate("quantile", q=0.9, feature=0),
        target_rel_err=0.02,
        use_sketches=False,
        seed=0,
    ):
        a = res["p90[0]"]
        lo_w = "-inf" if np.isneginf(a.ci_lo) else f"{a.ci_lo:.4f}"
        hi_w = "+inf" if np.isposinf(a.ci_hi) else f"{a.ci_hi:.4f}"
        print(f"{res.blocks_read:6d}  {a.estimate:8.4f}  [{lo_w}, {hi_w}]"
              f"  {res.max_rel_err:8.4f}")
        if res.converged:
            st = res.executor_stats
            print(f"-> converged after {res.blocks_read}/{res.total_blocks} blocks"
                  f" ({st.blocks_fetched} fetched, {st.hits} cache hits)")

    # the same machinery answers moment-only queries from the partition-time
    # sketches alone: zero block reads, exact corpus statistics
    res = ds.query(["mean", "var", "count"])
    print(f"sketch-only query: from_sketches={res.from_sketches}, "
          f"blocks_fetched={res.executor_stats.blocks_fetched}, "
          f"count={res['count'].estimate:.0f}")

    # out-of-core ingest: partition a chunked on-disk corpus into a stored
    # RSP without ever loading it whole -- chunks scatter straight to their
    # destination offsets and the sketches fold during the write, so the
    # finished store answers moment queries with zero block reads
    import os
    import tempfile

    with tempfile.TemporaryDirectory() as tmp:
        chunk_dir = os.path.join(tmp, "chunks")
        os.makedirs(chunk_dir)
        for c in range(8):  # the "distributed data set": 8 chunk files
            np.save(os.path.join(chunk_dir, f"part_{c:03d}.npy"),
                    data[c * (N // 8) : (c + 1) * (N // 8)])
        ds_stream = rsp.from_source(chunk_dir, blocks=K, seed=7,
                                    out=os.path.join(tmp, "corpus.rsp"))
        res = ds_stream.query(["mean", "count"])
        print(f"\nstreamed ingest of {len(os.listdir(chunk_dir))} chunk files -> "
              f"store-backed RSP ({ds_stream.backend}): "
              f"max|mean err| {np.abs(res['mean'].estimate - truth_mean).max():.2e}, "
              f"blocks read {res.executor_stats.blocks_fetched}")
        ds_stream.close()

    # sketch-guided selection: on a *skewed, contiguously-chunked* corpus
    # (NOT an RSP -- the pathological storage order), uniform block sampling
    # is at its worst; weighted PPS selection + Horvitz-Thompson reweighting
    # recovers the corpus mean from the same number of blocks
    rng = np.random.default_rng(0)
    skewed = np.sort(rng.lognormal(mean=1.0, sigma=1.2, size=64 * 512))
    chunked = rsp.RSPDataset(
        rsp.RSPSpec(num_records=64 * 512, num_blocks=64, num_original_blocks=1,
                    record_shape=(1,)),
        blocks=skewed.reshape(64, 512, 1).astype(np.float32),
    )
    truth = skewed.mean()
    uni = chunked.moments(g=8, seed=1).mean[0]
    wgt = chunked.moments(g=8, seed=1, policy="weighted").mean[0]
    print(f"skewed chunked corpus, g=8: true mean {truth:.3f}, "
          f"uniform {uni:.3f}, weighted+HT {wgt:.3f}")


if __name__ == "__main__":
    main()
