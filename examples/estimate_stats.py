"""Block-level statistics estimation (paper Sec. 8, Figs. 3/4): watch the
estimates converge to the full-data truth as blocks are added, with the
plateau detector stopping the scan early.

    PYTHONPATH=src python examples/estimate_stats.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import (
    BlockLevelEstimator,
    RSPSpec,
    block_histogram,
    quantile_from_histogram,
    two_stage_partition_np,
)
from repro.core.similarity import hotelling_t2, mmd_block_vs_data
from repro.data import make_higgs_like


def main():
    N, K = 200_000, 100
    x, y = make_higgs_like(N, seed=4)
    data = np.concatenate([x, y[:, None].astype(np.float32)], axis=1)
    spec = RSPSpec(num_records=N, num_blocks=K, num_original_blocks=K, seed=7)
    blocks = two_stage_partition_np(data, spec)
    truth_mean = data.mean(0)
    truth_std = data.std(0, ddof=1)

    est = BlockLevelEstimator()
    print("blocks  max|mean err|  max|std err|  converged?")
    for g in range(1, K + 1):
        est.update(jnp.asarray(blocks[g - 1]))
        conv = est.converged(rel_tol=1e-3)
        if g in (1, 2, 5, 10, 20) or conv:
            em = np.abs(est.stats.mean - truth_mean).max()
            es = np.abs(est.stats.std - truth_std).max()
            print(f"{g:6d}  {em:13.6f}  {es:12.6f}  {conv}")
        if conv:
            print(f"-> plateau after {g}/{K} blocks ({100 * g / K:.0f}% of the data)")
            break

    # distribution-level checks on one block (Sec. 7 toolkit)
    mmd = mmd_block_vs_data(blocks[3], data, seed=0)
    t2, f, p = hotelling_t2(blocks[3][:, :-1], data[:3000, :-1])
    print(f"block 3 vs data: MMD^2={mmd:.2e}, Hotelling T2 p-value={p:.3f}")

    # quantiles via combinable histograms
    h = sum(block_histogram(blocks[k], bins=256, lo=-8, hi=8) for k in range(5))
    q = quantile_from_histogram(h, [0.5], lo=-8, hi=8)[:, 0]
    true_q = np.quantile(data, 0.5, axis=0)
    print(f"median from 5 blocks: max abs err {np.abs(q - true_q).max():.4f}")


if __name__ == "__main__":
    main()
