"""Multi-tenant query serving over one stored RSP dataset.

Four tenants share one ``QueryService`` (one ``BlockExecutor`` block cache):
a dashboard refreshing exact moments from the sketches, an analyst's
progressive median, a batch job capped at a block budget, and an impatient
tenant whose unreachable accuracy target is cut off by a deadline -- who
still gets an *anytime* answer (estimate + CI + blocks consumed), not an
error.  A final saturation demo shows admission control rejecting instead
of queueing forever.

    PYTHONPATH=src python examples/serve_queries.py
"""

import os
import tempfile

import numpy as np

from repro import rsp
from repro.serve import AdmissionRejected


def show(tag, ticket, res):
    a = res[res.aggregates[0].name]
    print(f"{tag:>10}: outcome={ticket.outcome:<10} blocks={res.blocks_read:<3}"
          f" latency={ticket.latency_ms:6.1f}ms  {a.name}={np.round(a.estimate, 4)}")


def main():
    rng = np.random.default_rng(0)
    data = rng.normal(5.0, 1.0, size=(64 * 1024, 4)).astype(np.float32)

    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "corpus.rsp")
        ds = rsp.partition(data, blocks=64, seed=1)
        ds.save(path)
        ds.close()

        ds = rsp.open(path, cache_blocks=64)
        with ds.serve(capacity=16, workers=4, seed=7) as svc:
            # four tenants submit concurrently; nobody waits for anybody
            dashboard = svc.submit(["mean", "var", "count"])          # sketches
            analyst = svc.submit("median", target_rel_err=0.02,
                                 use_sketches=False, explain=True)
            batch = svc.submit("mean", max_blocks=4, use_sketches=False,
                               confidence=0.999)
            impatient = svc.submit("mean", target_rel_err=1e-12,
                                   policy="weighted", max_blocks=10**7,
                                   use_sketches=False, deadline_ms=300,
                                   explain=True)

            show("dashboard", dashboard, svc.result(dashboard))
            analyst_res = svc.result(analyst)
            show("analyst", analyst, analyst_res)
            show("batch", batch, svc.result(batch))
            res = svc.result(impatient)  # anytime answer AT the deadline
            show("impatient", impatient, res)
            a = res["mean"]
            truth = data.astype(np.float64).mean(0)
            covered = bool(np.all(a.ci_lo <= truth) & np.all(truth <= a.ci_hi))
            print(f"            anytime CI covers the full-scan mean: {covered}")

            # per-tenant convergence report: each explain=True tenant gets the
            # paper's error-vs-blocks trajectory for ITS OWN query, straight
            # off QueryResult.trace -- why did my answer stop when it did?
            for tag, r in [("analyst", analyst_res), ("impatient", res)]:
                if r.trace is not None:
                    print(f"\n[{tag}] {r.trace.report()}")

            m = svc.metrics()
            print(f"\nservice: {m.completed} completed, qps={m.qps:.0f}, "
                  f"p99={m.latency_p99_ms:.0f}ms, cache hit rate "
                  f"{m.cache_hit_rate:.2f}, blocks/query={m.blocks_per_query:.1f}")

        # saturation: capacity 1, no queue -> the second progressive query
        # is rejected up front instead of silently piling onto a busy service
        with ds.serve(capacity=1, max_queue=0, workers=1, seed=9) as svc:
            hog = svc.submit("mean", target_rel_err=1e-12, policy="weighted",
                             max_blocks=10**7, use_sketches=False)
            try:
                svc.submit("median", use_sketches=False)
            except AdmissionRejected as e:
                print(f"\nsaturated service rejected the second tenant: {e}")
            svc.cancel(hog)
        ds.close()


if __name__ == "__main__":
    main()
