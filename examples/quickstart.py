"""Quickstart: the RSP data model in ~60 lines.

Creates an RSP from a (deliberately class-sorted!) synthetic data set,
draws a block-level sample, estimates statistics from it, and trains a
small ensemble -- the paper's workflow end to end.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import (
    BlockLevelEstimator,
    BlockSampler,
    RSPSpec,
    asymptotic_ensemble_learn,
    make_logreg,
    two_stage_partition_np,
)
from repro.core.similarity import max_label_divergence
from repro.data import make_nonrandom_higgs_like


def main():
    # 1. a "big" data set, stored in the worst possible order (sorted by class)
    N, K = 40_000, 40
    x, y = make_nonrandom_higgs_like(N + 8_000, seed=0, class_sep=1.5)
    xe, ye = jnp.asarray(x[N:]), jnp.asarray(y[N:])
    data = np.concatenate([x[:N], y[:N, None].astype(np.float32)], axis=1)

    # 2. two-stage partitioning (Algorithm 1): every block becomes a random sample
    spec = RSPSpec(num_records=N, num_blocks=K, num_original_blocks=K, seed=1)
    blocks = two_stage_partition_np(data, spec)
    worst = max(max_label_divergence(blocks[k][:, -1], data[:, -1], 2) for k in range(K))
    print(f"RSP created: {K} blocks x {spec.block_size} records; "
          f"worst label divergence {worst:.4f} (sequential chunking: 0.50)")

    # 3. block-level sampling (Definition 4): no scan, no shuffle
    sampler = BlockSampler(K, seed=7)
    sample = sampler.sample(5)
    print(f"block-level sample: {sample}")

    # 4. estimate statistics from the sample alone (Sec. 8)
    est = BlockLevelEstimator()
    for b in sample:
        est.update(jnp.asarray(blocks[b][:, :-1]))
    err = float(np.abs(est.stats.mean - data[:, :-1].mean(0)).max())
    print(f"mean estimated from 5/{K} blocks; max abs error {err:.5f}")

    # 5. asymptotic ensemble learning (Algorithm 2)
    bx = jnp.asarray(blocks[:, :, :-1])
    by = jnp.asarray(blocks[:, :, -1].astype(np.int32))
    learner = make_logreg(bx.shape[-1], 2, steps=200, lr=0.5)
    ens, hist = asymptotic_ensemble_learn(
        bx, by, learner=learner, eval_x=xe, eval_y=ye, g=5, seed=0
    )
    print("ensemble accuracy per batch:", [round(a, 4) for a in hist.accuracy])
    print(f"final: {hist.accuracy[-1]:.4f} using {ens.num_models}/{K} blocks")


if __name__ == "__main__":
    main()
