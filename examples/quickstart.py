"""Quickstart: the RSP data model in ~40 lines, through the ``repro.rsp``
facade.

Creates an RSP from a (deliberately class-sorted!) synthetic data set,
draws a block-level sample, estimates statistics from the partition-time
block sketches, and trains a small ensemble -- the paper's workflow end
to end via one object.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro import rsp
from repro.data import make_nonrandom_higgs_like


def main():
    # 1. a "big" data set, stored in the worst possible order (sorted by class)
    N, K = 40_000, 40
    x, y = make_nonrandom_higgs_like(N + 8_000, seed=0, class_sep=1.5)
    xe, ye = x[N:], y[N:].astype(np.int32)
    data = np.concatenate([x[:N], y[:N, None].astype(np.float32)], axis=1)

    # 2. two-stage partitioning (Algorithm 1): every block becomes a random
    #    sample.  backend="auto" dispatches through the registry (shard_map
    #    with a mesh, the Pallas kernel on TPU, numpy streaming otherwise).
    ds = rsp.partition(data, blocks=K, seed=1, backend="auto", num_classes=2)
    print(f"RSP created: {ds.num_blocks} blocks x {ds.block_size} records "
          f"via backend={ds.backend!r}; worst label divergence "
          f"{ds.label_divergence():.4f} (sequential chunking: 0.50)")

    # 3. block-level sampling (Definition 4): no scan, no shuffle
    sample = ds.sample(5, seed=7)
    print(f"block-level sample: {sample}")

    # 4. estimate statistics from the sample alone (Sec. 8) -- the moments
    #    combine partition-time block sketches, touching no block data
    stats = ds.moments(ids=sample)
    err = float(np.abs(stats.mean[:-1] - data[:, :-1].mean(0)).max())
    print(f"mean estimated from 5/{K} blocks; max abs error {err:.5f}")

    # 5. asymptotic ensemble learning (Algorithm 2)
    learner = rsp.make_logreg(data.shape[1] - 1, 2, steps=200, lr=0.5)
    ens, hist = ds.ensemble(learner, eval_x=xe, eval_y=ye, g=5, seed=0)
    print("ensemble accuracy per batch:", [round(a, 4) for a in hist.accuracy])
    print(f"final: {hist.accuracy[-1]:.4f} using {ens.num_models}/{K} blocks")


if __name__ == "__main__":
    main()
